"""Shared benchmark substrate: one small trained model + calibration data.

The paper evaluates PTQ on pretrained Llama checkpoints; offline we train a
~10M-param llama-block model on the synthetic stream until it clearly learns
(loss ~ ln(V) -> ~2.5), cache it under experiments/bench_model, and run every
paper experiment against it. 20% of the eval stream is used for calibration
(matching the paper's split).
"""
from __future__ import annotations

import functools
import os

import jax
import numpy as np

from repro.configs.llama3_1b import bench_config
from repro.core.pipeline import AMPOptions, calibrate
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models.registry import build_model
from repro.quant.qops import QuantContext
from repro.train.optim import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

BENCH_DIR = os.environ.get("REPRO_BENCH_DIR", "experiments/bench_model")
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "150"))


@functools.cache
def bench_model():
    cfg = bench_config()
    model = build_model(cfg)
    data = SyntheticLM(SyntheticConfig(vocab_size=cfg.vocab_size, batch=8,
                                       seq_len=96, seed=5))
    mesh = make_local_mesh(1, 1)
    tr = Trainer(model, OptConfig(lr=1e-3, warmup_steps=20,
                                  total_steps=TRAIN_STEPS),
                 mesh, TrainerConfig(total_steps=TRAIN_STEPS, ckpt_every=100,
                                     ckpt_dir=BENCH_DIR, log_every=100))
    params, _, last_loss = tr.fit(data)
    return model, params, data, last_loss


@functools.cache
def bench_bundle():
    """One CalibrationBundle per (model, params): every figure benchmark
    solves its tau/objective grid from this artifact instead of
    recalibrating per sweep point. Cached on disk next to the checkpoint
    (params-fingerprint-validated), so across-process reruns skip the
    fwd+bwd calibration passes too."""
    model, params, data, _ = bench_model()
    calib = [data.batch_at(10_000 + i) for i in range(3)]
    cache = os.path.join(BENCH_DIR, "calibration_bundle.json")
    return calibrate(model, params, calib, AMPOptions(), cache=cache)


@functools.cache
def bench_sensitivity():
    return bench_bundle().sens


def eval_metrics(model, params, data, assignment=None, n_batches=4,
                 start=20_000):
    """(mean loss, next-token accuracy) on held-out batches."""
    import jax.numpy as jnp
    ctx = (QuantContext(mode="mp", mp=assignment) if assignment
           else QuantContext())
    losses, accs = [], []
    fwd = jax.jit(lambda p, t: model.apply(p, t, ctx))
    lossf = jax.jit(lambda p, b: model.loss(p, b, ctx))
    for i in range(n_batches):
        b = data.batch_at(start + i)
        losses.append(float(lossf(params, b)))
        logits = fwd(params, b["tokens"])
        pred = jnp.argmax(logits, axis=-1)
        accs.append(float(jnp.mean(pred == b["labels"])))
    return float(np.mean(losses)), float(np.mean(accs))


_ROWS: list = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    _ROWS.append({"name": name, "us": float(us_per_call), "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def write_rows_json(path: str) -> None:
    """Dump every ``emit`` row of this process as a JSON artifact (the
    BENCH_*.json trajectory files the ROADMAP tracks)."""
    import json
    with open(path, "w") as f:
        json.dump({"rows": _ROWS}, f, indent=2, sort_keys=True)
    print(f"# wrote {len(_ROWS)} benchmark rows to {path}")
