"""Paper Fig. 1: per-group time-gain measurement vs sum of per-layer
measurements for the attention sub-graph (q,k,v,qk,av = 2^5 configs).

On this host the quantized path is *simulated*, so absolute gains are
CPU-specific; the claim under test is structural: summing per-layer
measurements does NOT reproduce the jointly-measured group value, while the
group measurement is self-consistent. We report the mean absolute
discrepancy between the two estimators, plus the theoretical-time curve
(Sec. 2.3.2) for reference.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_bundle, bench_model, emit
from repro.core.timegain import TheoreticalGainModel, WallClockGainModel, enumerate_combos
from repro.hw.profiles import TPU_V5E
from repro.quant.qops import QuantContext

import jax


def main() -> None:
    model, params, data, _ = bench_model()
    bundle = bench_bundle()
    sens = bundle.sens
    op_index = {o.name: o for o in sens.ops}
    groups = bundle.objectives["ET"]["groups"]  # the Alg. 2 partition
    attn_group = next(g for g in groups if any("qk_matmul" in n for n in g))
    ops = [op_index[n] for n in attn_group]
    toks = data.batch_at(0)["tokens"][:4, :64]

    def factory(assignment):
        ctx = QuantContext(mode="mp", mp=assignment) if assignment else QuantContext()
        fn = jax.jit(lambda p, t: model.apply(p, t, ctx))

        def run():
            jax.block_until_ready(fn(params, toks))
        return run

    gm = WallClockGainModel(run_factory=factory, n_iters=5, n_warmup=2)
    combos = enumerate_combos(len(ops), ("bf16", "fp8_e4m3"))
    group_gains = gm.gains(ops, combos)

    # per-layer gains measured independently, then summed per combo
    per_layer = {}
    for op in ops:
        g = gm.gains([op], [("bf16",), ("fp8_e4m3",)])
        per_layer[op.name] = {"bf16": g[0], "fp8_e4m3": g[1]}
    summed = np.array([sum(per_layer[o.name][f] for o, f in zip(ops, combo))
                       for combo in combos])

    tt = TheoreticalGainModel(TPU_V5E).gains(ops, combos)

    disc = np.abs(group_gains - summed)
    base = gm.base_time()
    print("config,group_gain_s,sum_of_layers_s,theoretical_s")
    for combo, g, s, t in zip(combos, group_gains, summed, tt):
        label = "".join("1" if f != "bf16" else "0" for f in combo)
        print(f"{label},{g:.6f},{s:.6f},{t:.8f}")
    emit("fig1.group_vs_sum_mean_abs_discrepancy_us", float(np.mean(disc)) * 1e6,
         f"base_ttft_us={base*1e6:.1f}")
    emit("fig1.group_gain_spread_us",
         float(group_gains.max() - group_gains.min()) * 1e6,
         f"n_configs={len(combos)}")


if __name__ == "__main__":
    main()
