"""Paper Fig. 3: validation of the two additivity assumptions.

(a) loss-MSE model: theoretical d = sum_l s_l alpha_f (eq. 6/23) vs the
    measured E[(g_hat - g)^2] for IP-selected configurations across tau.
(b) time-gain additivity: sum of per-group measured gains vs the end-to-end
    measured gain of the full MP configuration (wall-clock tier).
"""
from __future__ import annotations

import numpy as np

import jax

from benchmarks.common import bench_bundle, bench_model, emit
from repro.core.timegain import WallClockGainModel
from repro.quant.qops import QuantContext


def main() -> None:
    model, params, data, _ = bench_model()
    bundle = bench_bundle()  # calibrated once; each tau is a cheap IP solve
    eval_batches = [data.batch_at(30_000 + i) for i in range(6)]
    loss_ref = jax.jit(lambda p, b: model.loss(p, b, QuantContext()))
    refs = [float(loss_ref(params, b)) for b in eval_batches]

    print("tau,predicted_mse,measured_mse,n_quantized")
    ratios = []
    for tau in (0.001, 0.002, 0.005, 0.01, 0.02, 0.05):
        plan = bundle.solve(tau=tau, objective="TT")
        ctx = QuantContext(mode="mp", mp=plan.assignment)
        lm = jax.jit(lambda p, b: model.loss(p, b, ctx))
        errs = [(float(lm(params, b)) - r) ** 2
                for b, r in zip(eval_batches, refs)]
        measured = float(np.mean(errs))
        print(f"{tau},{plan.predicted_loss_mse:.4e},{measured:.4e},"
              f"{plan.n_quantized}")
        if measured > 0 and plan.predicted_loss_mse > 0:
            ratios.append(plan.predicted_loss_mse / measured)
    emit("fig3a.pred_over_measured_mse_median", 0.0,
         f"ratio={np.median(ratios):.3f}")

    # (b) additivity of measured time gains across groups
    plan = bundle.solve(tau=0.02, objective="TT")
    toks = data.batch_at(0)["tokens"][:4, :64]

    def factory(assignment):
        c = QuantContext(mode="mp", mp=assignment) if assignment else QuantContext()
        fn = jax.jit(lambda p, t: model.apply(p, t, c))

        def run():
            jax.block_until_ready(fn(params, toks))
        return run

    gm = WallClockGainModel(run_factory=factory, n_iters=7, n_warmup=2)
    total = 0.0
    for group in plan.groups:
        sub = {n: plan.assignment[n] for n in group if n in plan.assignment}
        if not sub:
            continue
        t = gm._time(sub)
        total += gm.base_time() - t
    t_full = gm._time(plan.assignment)
    measured_full = gm.base_time() - t_full
    emit("fig3b.sum_group_gains_us", total * 1e6,
         f"measured_full_us={measured_full*1e6:.1f}")


if __name__ == "__main__":
    main()
