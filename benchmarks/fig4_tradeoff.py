"""Paper Fig. 4: loss-MSE vs time-gain curve — IP vs Random vs Prefix.

For a grid of gain levels we report the loss MSE each strategy pays:
the IP curve must dominate (same gain at lower MSE / more gain at equal
MSE). Gain metric: theoretical time (deterministic on CPU); the roofline-ET
variant is printed alongside.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_bundle, emit
from repro.core.baselines import prefix_strategy, random_strategy
from repro.core.pipeline import predicted_loss_mse
from repro.core.timegain import RooflineGainModel, TheoreticalGainModel
from repro.hw.profiles import TPU_V5E

TAUS = (0.001, 0.002, 0.005, 0.01, 0.02, 0.05)


def main() -> None:
    # the whole tau sweep solves from one calibration artifact (exactly one
    # sensitivity pass + gain enumeration across all six points)
    bundle = bench_bundle()
    sens = bundle.sens
    op_index = {o.name: o for o in sens.ops}
    names = [o.name for o in sens.ops]
    tt = TheoreticalGainModel(TPU_V5E)
    et = RooflineGainModel(TPU_V5E)

    def tt_gain(assignment):
        return sum(tt.op_gain(op_index[n], f) for n, f in assignment.items())

    def et_gain(assignment):
        return sum(et.op_time(op_index[n], "bf16") - et.op_time(op_index[n], f)
                   for n, f in assignment.items())

    print("tau,strategy,loss_mse,tt_gain_s,et_gain_s,n_quantized")
    dominated = 0
    total_pts = 0
    for tau, plan in zip(TAUS, bundle.pareto(TAUS, objective="TT")):
        budget = plan.budget
        rows = {
            "IP-TT": plan.assignment,
            "Random": random_strategy(names, sens, budget, seed=int(tau * 1e4)),
            "Prefix": prefix_strategy(names, sens, budget),
        }
        for strat, asg in rows.items():
            mse = predicted_loss_mse(sens, asg)
            print(f"{tau},{strat},{mse:.4e},{tt_gain(asg):.6e},"
                  f"{et_gain(asg):.6e},{len(asg)}")
            if strat != "IP-TT":
                total_pts += 1
                if tt_gain(asg) <= tt_gain(plan.assignment) + 1e-15:
                    dominated += 1
    emit("fig4.ip_dominates_fraction", 0.0, f"{dominated}/{total_pts}")


if __name__ == "__main__":
    main()
