"""Paper Fig. 8 (App. C.2): accuracy difference vs theoretical MAC-based
time gain, comparing IP-TT / Random / Prefix."""
from __future__ import annotations

from benchmarks.common import bench_bundle, bench_model, emit, eval_metrics
from repro.core.baselines import prefix_strategy, random_strategy
from repro.core.timegain import TheoreticalGainModel
from repro.hw.profiles import TPU_V5E


def main() -> None:
    model, params, data, _ = bench_model()
    bundle = bench_bundle()
    sens = bundle.sens
    names = [o.name for o in sens.ops]
    op_index = {o.name: o for o in sens.ops}
    gm = TheoreticalGainModel(TPU_V5E)
    loss0, acc0 = eval_metrics(model, params, data)

    def gain(asg):
        return sum(gm.op_gain(op_index[n], f) for n, f in asg.items())

    print("strategy,tau,tt_gain_s,d_acc")
    best = {}
    for tau in (0.002, 0.01, 0.05):
        plan = bundle.solve(tau=tau, objective="TT")
        budget = plan.budget
        for strat, asg in (("IP-TT", plan.assignment),
                           ("Random", random_strategy(names, sens, budget,
                                                      seed=9)),
                           ("Prefix", prefix_strategy(names, sens, budget))):
            _, acc = eval_metrics(model, params, data, assignment=asg,
                                  n_batches=3)
            print(f"{strat},{tau},{gain(asg):.6e},{acc - acc0:+.4f}")
            best.setdefault(strat, []).append(gain(asg))
    emit("fig8.ip_tt_gain_at_tau0.05", 0.0, f"{max(best['IP-TT']):.4e}")


if __name__ == "__main__":
    main()
