"""Paper Fig. 9 (App. C.3): accuracy difference vs total weight memory
under IP-M / Random / Prefix (linear layers only, eq. 25)."""
from __future__ import annotations

from benchmarks.common import bench_bundle, bench_model, emit, eval_metrics
from repro.core.baselines import prefix_strategy, random_strategy
from repro.core.timegain import MemoryGainModel


def main() -> None:
    model, params, data, _ = bench_model()
    bundle = bench_bundle()
    sens = bundle.sens
    gm = MemoryGainModel()
    op_index = {o.name: o for o in sens.ops}
    lin_names = [o.name for o in sens.ops if o.kind == "linear"]
    total_bytes = sum(o.weight_elems * 2 for o in sens.ops)
    loss0, acc0 = eval_metrics(model, params, data)

    def mem_after(asg):
        saved = sum(gm.op_gain(op_index[n], f) for n, f in asg.items())
        return total_bytes - saved

    print("strategy,tau,model_MB,d_acc")
    for tau in (0.002, 0.01, 0.05):
        plan = bundle.solve(tau=tau, objective="M")
        budget = plan.budget
        for strat, asg in (("IP-M", plan.assignment),
                           ("Random", random_strategy(lin_names, sens, budget,
                                                      seed=4)),
                           ("Prefix", prefix_strategy(lin_names, sens, budget))):
            _, acc = eval_metrics(model, params, data, assignment=asg,
                                  n_batches=3)
            print(f"{strat},{tau},{mem_after(asg)/1e6:.2f},{acc - acc0:+.4f}")
    emit("fig9.bf16_model_MB", 0.0, f"{total_bytes/1e6:.2f}")


if __name__ == "__main__":
    main()
