"""Kernel microbenchmarks.

Interpret-mode executes kernel bodies in Python (correctness only), so the
timing rows measure the XLA lowering of the *same computation* (the
deployment fallback path) plus the interpret-mode allclose check per shape.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, write_rows_json
from repro.kernels import ops, ref
from repro.kernels.quant_cast import quantize_fp8


def _time(fn, *args, iters=5):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="BENCH_kernels.json",
                    default=None, metavar="PATH",
                    help="also write the rows as JSON (default path "
                         "BENCH_kernels.json when the flag is given bare)")
    args = ap.parse_args()
    key = jax.random.key(0)
    print("kernel,shape,us_xla_path,interpret_ok")
    for (M, K, N) in ((256, 512, 256), (512, 1024, 512)):
        x = jax.random.normal(key, (M, K), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (N, K), jnp.float32)
        xq, sx = quantize_fp8(x, interpret=True)
        wq, sw = quantize_fp8(w, interpret=True)
        want = ref.fp8_matmul_ref(xq, wq, sx, sw)
        from repro.kernels import fp8_matmul
        got = fp8_matmul(xq, wq, sx, sw, block_m=128, block_n=128,
                         block_k=256, interpret=True)
        ok = bool(np.allclose(np.asarray(got, np.float32),
                              np.asarray(want, np.float32), rtol=2e-2,
                              atol=2e-2))
        fn = jax.jit(lambda a, b, s1, s2: ref.fp8_matmul_ref(a, b, s1, s2))
        us = _time(fn, xq, wq, sx, sw)
        print(f"fp8_matmul,{M}x{K}x{N},{us:.1f},{ok}")
        emit(f"kernels.fp8_matmul_{M}x{K}x{N}", us, f"allclose={ok}")

    B, H, T, D = 2, 4, 512, 64
    q = jax.random.normal(key, (B, H, T, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 2), (B, H, T, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 3), (B, H, T, D), jnp.float32)
    from repro.kernels import mp_flash_attention
    got = mp_flash_attention(q, k, v, causal=True, block_q=128, block_k=128,
                             interpret=True)
    want = ref.mp_flash_attention_ref(q, k, v, causal=True)
    ok = bool(np.allclose(np.asarray(got, np.float32),
                          np.asarray(want, np.float32), rtol=5e-2, atol=5e-3))
    fn = jax.jit(lambda a, b, c: ref.mp_flash_attention_ref(a, b, c))
    us = _time(fn, q, k, v)
    emit(f"kernels.mp_flash_attention_{B}x{H}x{T}x{D}", us, f"allclose={ok}")

    paged_attention_rows(key)

    if args.json:
        write_rows_json(args.json)


def paged_attention_rows(key) -> None:
    """Fused paged-decode kernel vs the gather XLA path across block counts
    and live-token fractions. The kernel itself runs interpret-mode
    (correctness); the timing rows compare the XLA gather computation at
    full provisioned capacity vs restricted to each row's live pages — the
    read set the fused kernel touches — so the capacity-vs-live traffic gap
    the kernel closes is visible in XLA wall time too."""
    import math

    from repro.kernels.paged_attention import paged_decode_attention

    B, Hkv, G, D, bs = 4, 2, 2, 64, 16
    for n_pages, live_frac in ((8, 0.25), (8, 0.5), (16, 0.25), (16, 0.125)):
        n_blocks = 1 + B * n_pages
        k1, k2, k3 = (jax.random.fold_in(key, 10 + i) for i in range(3))
        kc = jax.random.normal(k1, (n_blocks, bs, Hkv, D),
                               jnp.float32).astype(jnp.bfloat16)
        vc = jax.random.normal(k2, (n_blocks, bs, Hkv, D),
                               jnp.float32).astype(jnp.bfloat16)
        q = jax.random.normal(k3, (B, Hkv, G, D),
                              jnp.float32).astype(jnp.bfloat16)
        live = max(int(live_frac * n_pages * bs), 1)
        live_pages = -(-live // bs)
        lengths = jnp.full((B,), live, jnp.int32)
        tables = np.full((B, n_pages), -1, np.int32)
        ids = iter(range(1, n_blocks))
        for b in range(B):
            for pg in range(live_pages):
                tables[b, pg] = next(ids)
        bt = jnp.asarray(tables)
        kw = dict(scale=math.sqrt(D), scale_mode="div",
                  score_dtype=jnp.bfloat16, probs_dtype=jnp.bfloat16,
                  out_dtype=jnp.bfloat16)
        got = paged_decode_attention(q, kc, vc, bt, lengths, interpret=True,
                                     **kw)
        want = ref.paged_decode_attention_ref(q, kc, vc, bt, lengths, **kw)
        ok = bool(np.allclose(np.asarray(got, np.float32),
                              np.asarray(want, np.float32),
                              rtol=1e-2, atol=1e-5))
        fn = jax.jit(lambda qq, kk, vv, tt, ln: ref.paged_decode_attention_ref(
            qq, kk, vv, tt, ln, **kw))
        us_capacity = _time(fn, q, kc, vc, bt, lengths)
        us_live = _time(fn, q, kc, vc, bt[:, :live_pages], lengths)
        blk_kb = 2 * bs * Hkv * D * kc.dtype.itemsize / 1024
        emit(f"kernels.paged_attention_p{n_pages}_live{live_frac}",
             us_capacity,
             f"gather XLA at capacity ({n_pages} pages/row, "
             f"{n_pages * blk_kb:.0f} KB KV read/row); live-only "
             f"{us_live:.1f}us ({live_pages} pages, "
             f"{live_pages * blk_kb:.0f} KB — the fused kernel's read set); "
             f"fused interpret allclose={ok}")


if __name__ == "__main__":
    main()
