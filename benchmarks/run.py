"""Run every paper-table/figure benchmark; prints ``name,us_per_call,derived``
CSV lines (via common.emit) interleaved with the per-benchmark tables."""
from __future__ import annotations

import time
import traceback


def main() -> None:
    from benchmarks import (fig1_group_additivity, fig3_validation,
                            fig4_tradeoff, fig8_macs, fig9_memory,
                            kernels_bench, serve_throughput, table1_accuracy)
    benches = [
        ("fig1_group_additivity", fig1_group_additivity.main),
        ("fig3_validation", fig3_validation.main),
        ("fig4_tradeoff", fig4_tradeoff.main),
        ("table1_accuracy", table1_accuracy.main),
        ("fig8_macs", fig8_macs.main),
        ("fig9_memory", fig9_memory.main),
        ("kernels_bench", kernels_bench.main),
        ("serve_throughput", serve_throughput.main),
    ]
    failures = 0
    for name, fn in benches:
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"[{name}] done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmarks failed")


if __name__ == "__main__":
    main()
