"""Serving throughput: continuous batching (paged and dense-slot KV) vs the
one-shot baseline under a mixed (staggered) request arrival pattern.

Emits (via common.emit) tokens/s and per-request TTFT for both engines, with
and without the IP-solved MP plan — plus the KV-cache memory economics the
paged refactor exists for: peak block occupancy and KV HBM bytes per live
token, paged vs the dense-slot baseline at the same batch pressure. The run
fails if paged bytes/live-token is not strictly below dense, or if any
engine pair disagrees on greedy tokens.

The one-shot baseline must wait for the whole batch to arrive before
prefilling (batch-formation latency), so its effective TTFT for early
requests includes the queueing wait; the continuous engine admits each
request the moment a slot (and, paged, its block budget) frees up.

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--requests 8] [--n-slots 4] [--new-tokens 12] [--block-size 8]
"""
from __future__ import annotations

import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_bundle, bench_model, emit
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine


def _requests(data, n, prompt_len, new_tokens, arrival_every):
    return [Request(rid=i,
                    tokens=np.asarray(
                        data.batch_at(60_000 + i)["tokens"][0, :prompt_len],
                        np.int32),
                    max_new_tokens=new_tokens,
                    arrival=i * arrival_every)
            for i in range(n)]


def run_continuous(model, params, reqs, n_slots, max_len, mp, tag,
                   paged=True, block_size=16):
    eng = ContinuousBatchingEngine(model, n_slots=n_slots, max_len=max_len,
                                   mp=mp, paged=paged, block_size=block_size)
    eng.serve(params, [reqs[0]])              # warmup (compile)
    out = eng.serve(params, reqs)
    ttfts = np.array(sorted(r.ttft_s for r in out.results.values()))
    layout = "paged" if paged else "dense"
    emit(f"serve_continuous_{layout}_{tag}_tok_s", out.tokens_per_s,
         f"{out.n_steps} steps, {len(reqs)} reqs, {n_slots} slots")
    emit(f"serve_continuous_{layout}_{tag}_ttft_p50_us",
         ttfts[len(ttfts) // 2] * 1e6, "prefill wall time at admission")
    c = out.counters
    # the paging win, measured: HBM the KV cache actually pins per live
    # token at peak batch pressure (dense pins n_slots * max_len regardless)
    emit(f"serve_continuous_{layout}_{tag}_kv_bytes_per_live_token",
         c["peak_kv_bytes"] / max(c["peak_live_tokens"], 1),
         f"peak KV {c['peak_kv_bytes'] / 1e6:.3f} MB over "
         f"{c['peak_live_tokens']} live tokens")
    if paged:
        emit(f"serve_continuous_{layout}_{tag}_peak_blocks",
             c["peak_blocks_in_use"],
             f"of {c['n_blocks'] - 1} allocatable, block_size "
             f"{c['block_size']}, {c['blocked_admissions']} blocked admissions")
    return out


def run_oneshot(model, params, reqs, mp, tag):
    """Batch all requests at once (same prompt length) and decode lock-step."""
    eng = ServeEngine(model, mp=mp, donate=False)
    toks = jnp.asarray(np.stack([r.tokens for r in reqs]))
    new_tokens = reqs[0].max_new_tokens
    max_len = toks.shape[1] + new_tokens
    # warmup at the same max_len so the measured run reuses the compile
    eng.generate(params, {"tokens": toks}, max_new_tokens=2, max_len=max_len)
    out = eng.generate(params, {"tokens": toks}, max_new_tokens=new_tokens,
                       max_len=max_len)
    emit(f"serve_oneshot_{tag}_tok_s", out.tokens_per_s,
         f"batch {len(reqs)} lock-step decode")
    emit(f"serve_oneshot_{tag}_ttft_us", out.ttft_s * 1e6,
         "batched prefill wall time (excl. batch-formation wait)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--arrival-every", type=int, default=2)
    ap.add_argument("--tau", type=float, default=0.01)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=None,
                    help="continuous-engine cache ceiling (default 2x the "
                         "request span: engines are provisioned for their "
                         "longest admissible request, and paging only pays "
                         "for live tokens inside that ceiling)")
    args = ap.parse_args()

    model, params, data, _ = bench_model()
    plan = bench_bundle().solve(tau=args.tau, objective="ET")
    print(f"# MP plan quantizes {plan.n_quantized}/{plan.meta['n_ops']} ops")

    reqs = _requests(data, args.requests, args.prompt_len, args.new_tokens,
                     args.arrival_every)
    max_len = args.max_len or 2 * (args.prompt_len + args.new_tokens)

    for tag, mp in (("bf16", None), ("mp", plan)):
        one = run_oneshot(model, params, reqs, mp, tag)
        dense = run_continuous(model, params, reqs, args.n_slots, max_len,
                               mp, tag, paged=False)
        paged = run_continuous(model, params, reqs, args.n_slots, max_len,
                               mp, tag, paged=True,
                               block_size=args.block_size)
        # parity guard: the benchmark is only meaningful if all engines
        # generate the same greedy continuations
        batch_toks = np.asarray(one.tokens)
        for name, cont in (("dense", dense), ("paged", paged)):
            agree = np.mean([
                np.array_equal(cont.results[i].tokens, batch_toks[i])
                for i in range(args.requests)])
            print(f"# {tag}: one-shot vs continuous[{name}] greedy "
                  f"agreement {agree:.2%}")
            if agree < 1.0:
                raise SystemExit(
                    f"token-parity violation ({tag}/{name}): continuous and "
                    f"one-shot engines disagree — comparison is invalid")
        # the acceptance bar: paged KV must pin strictly fewer HBM bytes per
        # live token than dense slots at the same batch pressure
        bpl = {name: c.counters["peak_kv_bytes"]
               / max(c.counters["peak_live_tokens"], 1)
               for name, c in (("dense", dense), ("paged", paged))}
        print(f"# {tag}: KV bytes/live-token paged {bpl['paged']:.1f} vs "
              f"dense {bpl['dense']:.1f} "
              f"({bpl['paged'] / bpl['dense']:.1%} of dense)")
        if bpl["paged"] >= bpl["dense"]:
            raise SystemExit(
                f"paging regression ({tag}): paged KV bytes/live-token "
                f"{bpl['paged']:.1f} not below dense {bpl['dense']:.1f}")


if __name__ == "__main__":
    main()
