"""Serving throughput: continuous batching (paged and dense-slot KV) vs the
one-shot baseline under a mixed (staggered) request arrival pattern.

Emits (via common.emit) tokens/s and per-request TTFT for both engines, with
and without the IP-solved MP plan — plus the KV-cache memory economics the
paged refactor exists for: peak block occupancy and KV HBM bytes per live
token, paged vs the dense-slot baseline at the same batch pressure, and the
chunked/bucketed prefill economics: compiled prefill programs (buckets) vs
distinct prompt lengths, and the p50/p99 decode-step stall injected while a
deliberately long prompt prefills in chunks — and, since the fused
paged-attention kernel, the per-decode-step attention KV bytes read:
live-token-proportional for the fused kernel vs capacity-proportional for
the gather reference path — and, since the pipelined drain, the host/device
overlap economics: host-blocked seconds per decode step for the lockstep
(sync) vs pipelined engine on the same stream, readback batching, and peak
pipeline depth, written to ``BENCH_serve.json`` — and, since prefix
caching, the shared-prefix economics: prefill chunks, follower TTFT and
prefix-hit rate on an 80%-shared workload with sharing on vs off. The run
fails if paged bytes/live-token is not strictly below dense, if fused
attention reads are not strictly below gather at <= 50% occupancy, if
bucketing does not cut prefill compilations by at least 2x on the
mixed-length stream, if the decode stall exceeds the chunk budget, if the
pipelined drain does not block the host strictly less per decode step than
the lockstep drain (with streamed tokens bit-identical to it), if prefix
sharing does not cut prefill chunks by at least 2x on the shared workload
(with tokens bit-identical to the no-sharing run), or if any engine pair
disagrees on greedy tokens.

Since load-adaptive MP, a bursty-trace leg (``adaptive_tau_economics``)
drives the solver<->scheduler loop under two arrival bursts and fails
unless (a) the adaptive-tau arm completes a downshift->restore cycle,
(b) its p95 modeled TTFT holds an SLA the fixed base plan misses, and
(c) the control arm — an adaptive engine whose single-level ladder can
never swap — is greedy-token bit-identical to the plain fixed-plan
engine. Both arms' per-request TTFTs land under the ``adaptive`` key of
``BENCH_serve.json`` (TTFT is CPU-*modeled* in reference step units — see
the leg's docstring).

Since the fault-tolerant serving work, a guardrail-overhead leg
(``guardrail_overhead_economics``) prices the tau-anchored numerical
guardrail's periodic high-precision shadow step: interleaved (off, on)
drain pairs must keep the best-pair wall-throughput ratio >= 0.98 (<= 2%
overhead), greedy tokens bit-identical, and an honest plan must never
breach. The payload lands under the ``guardrail`` key of
``BENCH_serve.json``.

The one-shot baseline must wait for the whole batch to arrive before
prefilling (batch-formation latency), so its effective TTFT for early
requests includes the queueing wait; the continuous engine admits each
request the moment a slot (and, paged, its block budget) frees up.

    PYTHONPATH=src python benchmarks/serve_throughput.py \
        [--requests 8] [--n-slots 4] [--new-tokens 12] [--block-size 8]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_bundle, bench_model, emit
from repro.hw.profiles import get_profile
from repro.serve import (AdaptiveMPController, ContinuousBatchingEngine,
                         NumericalGuardrail, Request, ServeEngine)


def _requests(data, n, prompt_len, new_tokens, arrival_every):
    return [Request(rid=i,
                    tokens=np.asarray(
                        data.batch_at(60_000 + i)["tokens"][0, :prompt_len],
                        np.int32),
                    max_new_tokens=new_tokens,
                    arrival=i * arrival_every)
            for i in range(n)]


def run_continuous(model, params, reqs, n_slots, max_len, mp, tag,
                   paged=True, block_size=16, paged_attn=None):
    eng = ContinuousBatchingEngine(model, n_slots=n_slots, max_len=max_len,
                                   mp=mp, paged=paged, block_size=block_size,
                                   paged_attn=paged_attn)
    eng.serve(params, [reqs[0]])              # warmup (compile)
    out = eng.serve(params, reqs)
    ttfts = np.array(sorted(r.ttft_s for r in out.results.values()))
    layout = ("paged" if paged_attn in (None, "fused") else "paged_gather") \
        if paged else "dense"
    emit(f"serve_continuous_{layout}_{tag}_tok_s", out.tokens_per_s,
         f"{out.n_steps} steps, {len(reqs)} reqs, {n_slots} slots")
    emit(f"serve_continuous_{layout}_{tag}_ttft_p50_us",
         ttfts[len(ttfts) // 2] * 1e6, "prefill wall time at admission")
    c = out.counters
    # the paging win, measured: HBM the KV cache actually pins per live
    # token at peak batch pressure (dense pins n_slots * max_len regardless)
    emit(f"serve_continuous_{layout}_{tag}_kv_bytes_per_live_token",
         c["peak_kv_bytes"] / max(c["peak_live_tokens"], 1),
         f"peak KV {c['peak_kv_bytes'] / 1e6:.3f} MB over "
         f"{c['peak_live_tokens']} live tokens")
    if paged:
        emit(f"serve_continuous_{layout}_{tag}_peak_blocks",
             c["peak_blocks_in_use"],
             f"of {c['n_blocks'] - 1} allocatable, block_size "
             f"{c['block_size']}, {c['blocked_admissions']} blocked admissions")
    return out


def run_oneshot(model, params, reqs, mp, tag):
    """Batch all requests at once (same prompt length) and decode lock-step."""
    eng = ServeEngine(model, mp=mp, donate=False)
    toks = jnp.asarray(np.stack([r.tokens for r in reqs]))
    new_tokens = reqs[0].max_new_tokens
    max_len = toks.shape[1] + new_tokens
    # warmup at the same max_len so the measured run reuses the compile
    eng.generate(params, {"tokens": toks}, max_new_tokens=2, max_len=max_len)
    out = eng.generate(params, {"tokens": toks}, max_new_tokens=new_tokens,
                       max_len=max_len)
    emit(f"serve_oneshot_{tag}_tok_s", out.tokens_per_s,
         f"batch {len(reqs)} lock-step decode")
    emit(f"serve_oneshot_{tag}_ttft_us", out.ttft_s * 1e6,
         "batched prefill wall time (excl. batch-formation wait)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--arrival-every", type=int, default=2)
    ap.add_argument("--tau", type=float, default=0.01)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=None,
                    help="continuous-engine cache ceiling (default 2x the "
                         "request span: engines are provisioned for their "
                         "longest admissible request, and paging only pays "
                         "for live tokens inside that ceiling)")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="where to write the host/device overlap counters "
                         "(sync vs pipelined drain)")
    ap.add_argument("--adaptive-base-tau", type=float, default=1e-5,
                    help="level-0 tau of the bursty-trace adaptive leg "
                         "(deliberately tight: the bench model's "
                         "sensitivities are tiny, so headroom for the "
                         "ladder only exists at small taus)")
    ap.add_argument("--adaptive-levels", type=int, default=3)
    ap.add_argument("--adaptive-factor", type=float, default=10.0)
    ap.add_argument("--guardrail-every", type=int, default=16,
                    help="shadow-step cadence of the guardrail-overhead "
                         "leg (one high-precision decode step per N real "
                         "ones; the leg gates on <= 2% wall overhead)")
    ap.add_argument("--burst-gap", type=int, default=40,
                    help="engine ticks between the two arrival bursts of "
                         "the adaptive leg (sized so the queue fully "
                         "drains in between: one downshift/restore cycle "
                         "per burst)")
    args = ap.parse_args()

    model, params, data, _ = bench_model()
    plan = bench_bundle().solve(tau=args.tau, objective="ET")
    print(f"# MP plan quantizes {plan.n_quantized}/{plan.meta['n_ops']} ops")

    reqs = _requests(data, args.requests, args.prompt_len, args.new_tokens,
                     args.arrival_every)
    max_len = args.max_len or 2 * (args.prompt_len + args.new_tokens)

    for tag, mp in (("bf16", None), ("mp", plan)):
        one = run_oneshot(model, params, reqs, mp, tag)
        dense = run_continuous(model, params, reqs, args.n_slots, max_len,
                               mp, tag, paged=False)
        paged = run_continuous(model, params, reqs, args.n_slots, max_len,
                               mp, tag, paged=True,
                               block_size=args.block_size)
        engines = [("dense", dense), ("paged", paged)]
        if tag == "bf16":
            # gather reference engine: same drain, capacity-proportional
            # attention reads — the traffic baseline the fused kernel beats
            gather = run_continuous(model, params, reqs, args.n_slots,
                                    max_len, mp, tag, paged=True,
                                    block_size=args.block_size,
                                    paged_attn="gather")
            engines.append(("paged_gather", gather))
            attn_read_economics(paged, gather)
        # parity guard: the benchmark is only meaningful if all engines
        # generate the same greedy continuations
        batch_toks = np.asarray(one.tokens)
        for name, cont in engines:
            agree = np.mean([
                np.array_equal(cont.results[i].tokens, batch_toks[i])
                for i in range(args.requests)])
            print(f"# {tag}: one-shot vs continuous[{name}] greedy "
                  f"agreement {agree:.2%}")
            if agree < 1.0:
                raise SystemExit(
                    f"token-parity violation ({tag}/{name}): continuous and "
                    f"one-shot engines disagree — comparison is invalid")
        # the acceptance bar: paged KV must pin strictly fewer HBM bytes per
        # live token than dense slots at the same batch pressure
        bpl = {name: c.counters["peak_kv_bytes"]
               / max(c.counters["peak_live_tokens"], 1)
               for name, c in (("dense", dense), ("paged", paged))}
        print(f"# {tag}: KV bytes/live-token paged {bpl['paged']:.1f} vs "
              f"dense {bpl['dense']:.1f} "
              f"({bpl['paged'] / bpl['dense']:.1%} of dense)")
        if bpl["paged"] >= bpl["dense"]:
            raise SystemExit(
                f"paging regression ({tag}): paged KV bytes/live-token "
                f"{bpl['paged']:.1f} not below dense {bpl['dense']:.1f}")

    chunked_prefill_economics(model, params, data, args)
    shared = shared_prefix_economics(model, params, data, args)
    adaptive = adaptive_tau_economics(model, params, data, args)
    guardrail = guardrail_overhead_economics(model, params, plan, reqs,
                                             args, max_len)
    mesh = mesh_leg_economics(args)
    pipeline_overlap_economics(model, params, reqs, args, max_len,
                               mesh_payload=mesh, shared_prefix_payload=shared,
                               adaptive_payload=adaptive,
                               guardrail_payload=guardrail)


def shared_prefix_economics(model, params, data, args):
    """80%-shared-prefix workload through the prefix cache: every request
    carries the same block-aligned base prompt plus a distinct tail, sharing
    on vs off on the identical stream. With sharing on, the first request
    prefills the whole prompt and registers its full blocks; every later
    request matches the chain, claims the shared blocks by reference and
    prefills only its tail — so prefill chunks and TTFT for the followers
    collapse while greedy tokens stay bit-identical to the no-sharing run.

    Co-batching is off for both runs so ``prefill_chunks`` counts map 1:1 to
    prefill work (cobatch merges steps and would blur the ratio); arrivals
    are spaced so request 0 finishes (and registers) before any follower is
    admitted — the steady-state shape a shared system prompt produces.

    Fails unless sharing cuts prefill chunks by at least 2x or if any greedy
    token differs between the two runs."""
    chunk_len = 8
    bs = args.block_size
    # ~80% of the prompt, aligned UP to a block so every shared token sits
    # in a matchable full block (floor-aligning spills up to a block's worth
    # of shared tokens into the per-request tail and dilutes the leg)
    shared_len = -(-(4 * args.prompt_len) // 5 // bs) * bs
    shared_len = max(min(shared_len, (args.prompt_len - 1) // bs * bs), bs)
    tail = max(args.prompt_len - shared_len, 1)
    base = np.asarray(data.batch_at(80_000)["tokens"][0, :shared_len],
                      np.int32)
    first_done = -(-(shared_len + tail) // chunk_len) + 1
    reqs = [Request(rid=i,
                    tokens=np.concatenate([
                        base,
                        np.asarray(
                            data.batch_at(80_001 + i)["tokens"][0, :tail],
                            np.int32)]),
                    max_new_tokens=args.new_tokens,
                    arrival=0 if i == 0 else first_done + i)
            for i in range(args.requests)]
    max_len = 2 * (shared_len + tail + args.new_tokens)

    def drain(prefix_cache):
        eng = ContinuousBatchingEngine(
            model, n_slots=args.n_slots, max_len=max_len, paged=True,
            block_size=bs, chunk_len=chunk_len, prefill_cobatch=False,
            prefix_cache=prefix_cache)
        eng.serve(params, [reqs[0]])            # warmup (compile)
        return eng.serve(params, reqs)

    on, off = drain(True), drain(False)
    for r in reqs:
        if not np.array_equal(on.results[r.rid].tokens,
                              off.results[r.rid].tokens):
            raise SystemExit(
                f"prefix-cache parity violation: rid {r.rid} greedy tokens "
                f"differ between sharing-on and sharing-off")
    con, coff = on.counters, off.counters
    hit_rate = con["prefix_hit_requests"] / max(len(reqs) - 1, 1)
    ttft = lambda o: float(np.median(
        [o.results[r.rid].ttft_s for r in reqs[1:]]))
    emit("serve_prefix_prefill_chunks_shared", con["prefill_chunks"],
         f"vs {coff['prefill_chunks']} without sharing "
         f"({con['prefill_tokens']} vs {coff['prefill_tokens']} prompt "
         f"tokens prefilled)")
    emit("serve_prefix_follower_ttft_p50_us", ttft(on) * 1e6,
         f"vs {ttft(off) * 1e6:.0f} us without sharing "
         f"({shared_len}/{shared_len + tail} tokens shared)")
    emit("serve_prefix_hit_rate", hit_rate,
         f"{con['prefix_hit_requests']}/{len(reqs) - 1} follower requests, "
         f"{con['prefix_hit_tokens']} tokens skipped, "
         f"{con['cow_forks']} COW forks")
    print(f"# shared-prefix leg: {con['prefill_chunks']} prefill chunks "
          f"with sharing vs {coff['prefill_chunks']} without "
          f"({coff['prefill_chunks'] / max(con['prefill_chunks'], 1):.1f}x), "
          f"tokens bit-identical")
    if 2 * con["prefill_chunks"] > coff["prefill_chunks"]:
        raise SystemExit(
            f"prefix-cache regression: sharing ran {con['prefill_chunks']} "
            f"prefill chunks, not >= 2x below the no-sharing run's "
            f"{coff['prefill_chunks']} on an 80%-shared stream")
    keep = ("prefill_chunks", "prefill_tokens", "prefix_hit_requests",
            "prefix_hit_blocks", "prefix_hit_tokens", "cow_forks",
            "blocked_admissions")
    return {
        "requests": len(reqs), "shared_len": int(shared_len),
        "prompt_len": int(shared_len + tail), "chunk_len": chunk_len,
        "prefix_hit_rate": hit_rate,
        "follower_ttft_p50_s": {"sharing": ttft(on), "no_sharing": ttft(off)},
        "sharing": {k: con[k] for k in keep},
        "no_sharing": {k: coff[k] for k in keep if k in coff},
    }


def adaptive_tau_economics(model, params, data, args):
    """Bursty-trace SLA leg: load-adaptive tau vs the fixed base plan.

    **TTFT is CPU-modeled, loudly.** Fake-quant on CPU gives no real
    speedup, so wall-clock TTFT cannot distinguish the plans here. Both
    arms run REAL bursty drains — real scheduler, real step clock, real
    controller swaps at real step boundaries — and each request's TTFT is
    then priced deterministically in *reference step units*: every engine
    tick between arrival and first token costs ``1 - g(plan active at that
    tick)``, where ``g`` is the active plan's theoretical (TT) gain
    fraction, ``predicted_gain / t_ref`` over the bundle's calibrated ops
    on the bundle's hardware profile. On an accelerator the same leg would
    price ticks with measured step walls; the step-clock arithmetic
    (``first_token_step``, swap steps) is identical either way.

    Three arms, three gates:

    * **fixed** — a plain engine pinned to the base (level-0) plan. Its
      queued burst requests wait out cheap-plan ticks only.
    * **control** — the adaptive engine with a single-level ladder (it can
      never swap): greedy tokens must be *bit-identical* to the fixed arm,
      proving the controller machinery is parity-free when it cannot fire.
    * **adaptive** — a geometric tau ladder under the same bursty trace:
      must complete >= 1 downshift AND >= 1 restore, and its p95 modeled
      TTFT must hold an SLA the fixed plan misses (the SLA is recorded as
      the midpoint of the two p95s; the gate is
      ``adaptive_p95 <= sla < fixed_p95``).

    The TT objective (not ET/roofline) prices the ladder: the ~4M-param
    bench model is so small that roofline requant overhead swamps every
    op's gain, leaving ET no headroom to escalate into.
    """
    bundle = bench_bundle()
    hw = get_profile(bundle.meta.get("hw", "tpu_v5e"))
    t_ref = sum(op.macs * hw.mac_time(bundle.ref_format)
                for op in bundle.sens.ops)

    n = args.requests
    burst = _requests(data, 2 * n, args.prompt_len, args.new_tokens, 0)
    for r in burst[n:]:
        r.arrival = args.burst_gap            # two all-at-once waves
    max_len = 2 * (args.prompt_len + args.new_tokens)
    # generous block budget + no prefix cache: occupancy stays an honest
    # live-token signal (cached blocks would ratchet it up across the
    # drain and hold the controller hot after the queue empties)
    n_blocks = 1 + 8 * args.n_slots * -(-max_len // args.block_size)
    eng_kw = dict(n_slots=args.n_slots, max_len=max_len,
                  block_size=args.block_size, n_blocks=n_blocks,
                  prefix_cache=False)

    def controller(n_levels):
        return AdaptiveMPController.from_bundle(
            bundle, args.adaptive_base_tau, n_levels=n_levels,
            factor=args.adaptive_factor, objective="TT",
            every=1, dwell=2, queue_high=max(2, args.n_slots // 2),
            queue_low=0)

    base_plan = bundle.solve(tau=args.adaptive_base_tau, objective="TT")
    fixed_eng = ContinuousBatchingEngine(model, mp=base_plan, **eng_kw)
    fixed_eng.serve(params, [burst[0]])       # warmup (compile)
    fixed = fixed_eng.serve(params, burst)

    ctrl0 = controller(1)                     # the never-firing control arm
    control_eng = ContinuousBatchingEngine(model, adaptive=ctrl0, **eng_kw)
    control_eng.serve(params, [burst[0]])
    control = control_eng.serve(params, burst)
    if control.counters["adaptive"]["swaps"]:
        raise SystemExit("adaptive control arm: a single-level ladder "
                         "has nowhere to swap, yet it swapped")
    for r in burst:
        if not np.array_equal(control.results[r.rid].tokens,
                              fixed.results[r.rid].tokens):
            raise SystemExit(
                f"adaptive control-arm parity violation (rid {r.rid}): an "
                f"engine whose controller cannot fire must be bit-identical "
                f"to the plain fixed-plan engine")

    ctrl = controller(args.adaptive_levels)
    adaptive_eng = ContinuousBatchingEngine(model, adaptive=ctrl, **eng_kw)
    adaptive_eng.serve(params, [burst[0]])
    out = adaptive_eng.serve(params, burst)
    ca = out.counters["adaptive"]
    if not (ca["downshifts"] >= 1 and ca["restores"] >= 1):
        raise SystemExit(
            f"adaptive leg: the burst must drive >= 1 downshift and >= 1 "
            f"restore, got {ca['downshifts']} / {ca['restores']} "
            f"(swaps at {[s['step'] for s in ca['swaps']]})")

    def gain_frac(level):
        g = ctrl.plan_for(level).predicted_gain / t_ref
        return min(max(g, 0.0), 0.95)

    def modeled_ttfts(result, swaps, n_steps):
        g = np.full(n_steps + 1, gain_frac(0))
        for s in swaps:
            g[s["step"]:] = gain_frac(s["level"])
        cost = 1.0 - g
        return {r.rid: float(np.sum(
            cost[r.arrival:result.results[r.rid].first_token_step + 1]))
            for r in burst}

    t_fixed = modeled_ttfts(fixed, [], fixed.n_steps)
    t_adapt = modeled_ttfts(out, ca["swaps"], out.n_steps)
    p95 = lambda d: float(np.percentile(np.asarray(list(d.values())), 95))
    f95, a95 = p95(t_fixed), p95(t_adapt)
    sla = 0.5 * (f95 + a95)
    emit("serve_adaptive_ttft_p95_fixed_steps", f95,
         f"base tau {args.adaptive_base_tau:g} "
         f"(gain frac {gain_frac(0):.3f})")
    emit("serve_adaptive_ttft_p95_adaptive_steps", a95,
         f"ladder {[f'{t:g}' for t in ctrl.taus]}, "
         f"{ca['downshifts']} downshifts / {ca['restores']} restores")
    if not (a95 <= sla < f95):
        raise SystemExit(
            f"adaptive-tau regression: adaptive p95 modeled TTFT {a95:.2f} "
            f"steps must hold an SLA ({sla:.2f}) the fixed plan "
            f"({f95:.2f}) misses — the load-adaptive ladder bought no "
            f"queued-burst headroom")
    print(f"# adaptive leg: TTFT p95 (modeled steps) fixed {f95:.2f} vs "
          f"adaptive {a95:.2f}; SLA {sla:.2f} held; swaps at "
          f"{[s['step'] for s in ca['swaps']]}")
    return {
        "modeled": True,
        "note": ("TTFT in reference step units priced by the TT gain "
                 "fraction of the plan active at each tick — CPU fake-"
                 "quant has no wall speedup; see adaptive_tau_economics"),
        "base_tau": args.adaptive_base_tau,
        "taus": list(ctrl.taus),
        "gain_frac_per_level": [gain_frac(i) for i in
                                range(len(ctrl.taus))],
        "burst": {"requests": 2 * n, "gap": args.burst_gap,
                  "n_slots": args.n_slots},
        "sla_ttft_steps": sla,
        "fixed": {"ttft_p95_steps": f95,
                  "ttft_steps": {str(k): v for k, v in t_fixed.items()},
                  "n_steps": fixed.n_steps},
        "adaptive": {"ttft_p95_steps": a95,
                     "ttft_steps": {str(k): v for k, v in t_adapt.items()},
                     "n_steps": out.n_steps,
                     "downshifts": ca["downshifts"],
                     "restores": ca["restores"],
                     "swaps": ca["swaps"],
                     "final_tau": ca["final_tau"]},
        "control_arm": {"bit_identical_to_fixed": True,
                        "taus": list(ctrl0.taus)},
    }


def guardrail_overhead_economics(model, params, plan, reqs, args, max_len):
    """Cost of the tau-anchored numerical guardrail: the same MP drain with
    the shadow step on (one high-precision decode step + a scalar logit-MSE
    readback every ``--guardrail-every`` real steps) vs off. The shadow runs
    over the same inputs before the real step and its cache writes are
    discarded, so greedy tokens must be bit-identical between the two runs
    — the guardrail is parity-free by construction, it only costs wall
    time, and the amortized cost model is ~1/every of decode compute.

    Drains run as interleaved (off, on) pairs and the gate asserts on the
    best per-pair wall ratio with a 2% floor (the same matched-pair shape
    as the pipelining gate: back-to-back pairs cancel machine-load drift).
    An honest plan (budget = its own predicted loss MSE) must never breach:
    the leg also fails if any shadow check fired a restore."""
    eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                   max_len=max_len, mp=plan,
                                   block_size=args.block_size)
    fresh = lambda: NumericalGuardrail(every=args.guardrail_every, margin=4.0)
    eng.serve(params, [reqs[0]])              # warmup (compile)
    eng.guardrail = fresh()
    eng.serve(params, reqs)                   # warm the shadow-step compile

    def run(grail):
        eng.guardrail = grail
        return eng.serve(params, reqs)

    pairs = [(run(None), run(fresh())) for _ in range(3)]
    for rid in pairs[0][0].results:
        for off, on in pairs:
            if not np.array_equal(off.results[rid].tokens,
                                  on.results[rid].tokens):
                raise SystemExit(
                    f"guardrail parity violation: rid {rid} greedy tokens "
                    f"differ with the shadow step on — the guardrail must "
                    f"be observation-only")
    wall = lambda o: o.counters["wall_tokens_per_s"]
    ratio = max(wall(on) / wall(off) for off, on in pairs)
    on_best = max((on for _, on in pairs), key=wall)
    g = on_best.counters["guardrail"]
    if not g["checks"]:
        raise SystemExit(
            f"guardrail leg: no shadow check ran over {on_best.n_steps} "
            f"decode steps at cadence {args.guardrail_every} — the leg "
            f"measured nothing")
    if g["breaches"] or g["restored_at"] is not None:
        raise SystemExit(
            f"guardrail false positive: an honest plan (budget = its own "
            f"predicted loss MSE) breached at MSE {g['last_mse']}")
    emit("serve_guardrail_overhead_ratio", ratio,
         f"wall tokens/s with shadow every {args.guardrail_every} steps / "
         f"without ({g['checks']} checks, 0 breaches)")
    print(f"# guardrail leg: best matched-pair wall ratio {ratio:.3f} at "
          f"cadence {args.guardrail_every} ({g['checks']} shadow checks, "
          f"last MSE {g['last_mse']:.3g}, 0 breaches), tokens bit-identical")
    if ratio < 0.98:
        raise SystemExit(
            f"guardrail overhead regression: in every matched (off, on) "
            f"pair the shadow step cost more than 2% wall throughput "
            f"(best ratio {ratio:.3f} at cadence {args.guardrail_every})")
    return {
        "every": args.guardrail_every,
        "checks": g["checks"],
        "last_mse": g["last_mse"],
        "best_pair_ratio": ratio,
        "wall_tokens_per_s": {"off": [wall(off) for off, _ in pairs],
                              "on": [wall(on) for _, on in pairs]},
    }


def pipeline_overlap_economics(model, params, reqs, args, max_len,
                               mesh_payload=None, shared_prefix_payload=None,
                               adaptive_payload=None, guardrail_payload=None):
    """Lockstep (sync) vs pipelined drain on the same request stream: the
    pipelined producer dispatches steps ahead of the host and must block
    strictly less per decode step than the lockstep loop, whose every step
    waits out a device->host token readback. Streamed tokens (the on_token
    callback) must be bit-identical to the sync engine's results — the
    overlap is free parity-wise. Both drains' counters go to --json.

    Throughput comparison note (the PR-6 anomaly, root-caused): the two
    modes measure ``decode_s`` differently — sync sums per-step dispatch +
    readback wall time, while async reports the wall span from the first
    decode dispatch to drain end, which *includes* interleaved prefill,
    admission bookkeeping and the pipeline drain. ``tokens_per_s`` built on
    those denominators is therefore not comparable across modes (async
    looked 20% slower while blocking the host 12x less). The fair metric is
    ``wall_tokens_per_s`` — decoded tokens over the submission-to-drain-end
    wall clock, measured identically in both modes — which is what the
    regression gate below asserts on. Drains run as *interleaved*
    (sync, pipelined) pairs and the gate asserts on the best per-pair
    ratio with a 2% noise floor — back-to-back pairs cancel the
    machine-load drift that comparing two separately-timed batches of
    drains soaks up (measured ±10% wall variance run-to-run on shared
    CPU). The honest CPU-sized claim is "pipelining does not cost wall
    throughput" — the CPU "device" computes on the host cores, so wall
    time is compute-bound in both modes and the dominant signal is the
    strict per-step host-blocked gate (~10x lower pipelined)."""
    eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                   max_len=max_len,
                                   block_size=args.block_size)
    eng.serve(params, [reqs[0]])                       # warmup (compile)
    eng.serve(params, reqs, sync=True)                 # warm both full-
    eng.serve(params, reqs)                            # stream mode paths
    streamed = {r.rid: [] for r in reqs}

    def run_async():
        for v in streamed.values():
            v.clear()
        return eng.serve(
            params, reqs,
            on_token=lambda rid, idx, tok: streamed[rid].append(tok))

    pairs = [(eng.serve(params, reqs, sync=True), run_async())
             for _ in range(3)]
    wall = lambda o: o.counters["wall_tokens_per_s"]
    sync_out = max((p[0] for p in pairs), key=wall)
    async_out = max((p[1] for p in pairs), key=wall)
    pair_ratio = max(wall(a) / wall(s) for s, a in pairs)
    for r in reqs:
        if not np.array_equal(np.asarray(streamed[r.rid], np.int32),
                              sync_out.results[r.rid].tokens):
            raise SystemExit(
                f"pipelined-drain parity violation: rid {r.rid} streamed "
                f"tokens differ from the sync engine")
    cs, ca = sync_out.counters, async_out.counters
    emit("serve_host_blocked_per_step_sync_us",
         cs["host_blocked_s_per_step"] * 1e6,
         f"{cs['n_readbacks']} per-step readbacks over {sync_out.n_steps} "
         f"steps")
    emit("serve_host_blocked_per_step_pipelined_us",
         ca["host_blocked_s_per_step"] * 1e6,
         f"{ca['n_readbacks']} batched readbacks (mean batch "
         f"{ca['readback_batch_mean']:.1f}), device "
         f"{ca['steps_in_flight_peak']} steps ahead at peak")
    keep = ("sync", "host_blocked_s", "host_blocked_s_per_step",
            "drain_wait_s", "n_readbacks", "readback_batch_max",
            "readback_batch_mean", "steps_in_flight_peak", "n_cancelled",
            "wall_tokens_per_s")
    payload = {
        "requests": len(reqs), "n_slots": args.n_slots,
        "new_tokens": args.new_tokens,
        "sync": {k: cs[k] for k in keep},
        "pipelined": {k: ca[k] for k in keep},
        "n_steps": {"sync": sync_out.n_steps, "pipelined": async_out.n_steps},
        # decode-phase-only throughput; NOT comparable across modes (the
        # denominators are measured differently — see docstring). Kept for
        # trajectory; compare wall_tokens_per_s instead.
        "tokens_per_s": {"sync": sync_out.tokens_per_s,
                         "pipelined": async_out.tokens_per_s},
        # the fair comparison: identical measurement window in both modes
        "wall_tokens_per_s": {"sync": cs["wall_tokens_per_s"],
                              "pipelined": ca["wall_tokens_per_s"],
                              "best_pair_ratio": pair_ratio},
    }
    if mesh_payload is not None:
        payload["mesh"] = mesh_payload
    if shared_prefix_payload is not None:
        payload["shared_prefix"] = shared_prefix_payload
    if adaptive_payload is not None:
        payload["adaptive"] = adaptive_payload
    if guardrail_payload is not None:
        payload["guardrail"] = guardrail_payload
    with open(args.json, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    print(f"# host/device overlap counters written to {args.json}")
    print(f"# wall tokens/s: sync {cs['wall_tokens_per_s']:.1f} vs "
          f"pipelined {ca['wall_tokens_per_s']:.1f} "
          f"(best matched-pair ratio {pair_ratio:.3f})")
    # the acceptance bar the pipeline restructure exists for: taking the
    # readback off the critical path must shrink per-step host-blocked time
    # AND must not lose end-to-end throughput under the fair window
    if ca["host_blocked_s_per_step"] >= cs["host_blocked_s_per_step"]:
        raise SystemExit(
            f"pipelining regression: pipelined drain blocked the host "
            f"{ca['host_blocked_s_per_step'] * 1e6:.1f} us/step, not below "
            f"the lockstep drain's "
            f"{cs['host_blocked_s_per_step'] * 1e6:.1f} us/step")
    if pair_ratio < 0.98:
        raise SystemExit(
            f"pipelining regression: in every matched (sync, pipelined) "
            f"drain pair the pipelined wall throughput came in more than "
            f"2% below lockstep (best ratio {pair_ratio:.3f}) under the "
            f"identical measurement window")


# The mesh leg runs in a subprocess so the parent keeps the real (single)
# device view: XLA_FLAGS device-count overrides must be set before jax
# initializes. Untrained smoke weights — throughput and parity don't need a
# trained model, and retraining bench_model per subprocess would dominate.
_MESH_LEG_SCRIPT = r'''
import json, os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import numpy as np
import jax
from repro.models.registry import get_model
from repro.launch.mesh import make_local_mesh
from repro.serve import ContinuousBatchingEngine, Request

cfg = json.loads(sys.argv[1])
model = get_model("llama3_1b", smoke=True)
params = model.init(jax.random.key(0))
rng = np.random.default_rng(7)
prompts = [rng.integers(1, 100, size=cfg["prompt_len"]).astype(np.int32)
           for _ in range(cfg["requests"])]


def serve(mesh):
    eng = ContinuousBatchingEngine(
        model, n_slots=cfg["n_slots"], max_len=cfg["max_len"],
        block_size=cfg["block_size"], mesh=mesh)
    reqs = lambda: [Request(rid=i, tokens=p,
                            max_new_tokens=cfg["new_tokens"],
                            arrival=i * cfg["arrival_every"])
                    for i, p in enumerate(prompts)]
    eng.serve(params, reqs()[:1])          # warmup (compile)
    return eng, eng.serve(params, reqs())

_, base = serve(None)
out = {"single_device": {
    "tokens_per_s": base.tokens_per_s,
    "wall_tokens_per_s": base.counters["wall_tokens_per_s"]},
    "configs": {}, "parity": True}
for d, m in [(2, 1), (1, 2), (2, 2)]:
    eng, run = serve(make_local_mesh(data=d, model=m))
    for rid in base.results:
        assert np.array_equal(base.tokens_for(rid), run.tokens_for(rid)), (
            "mesh parity violation", d, m, rid)
    n_dev = d * m
    wall = run.counters["wall_tokens_per_s"]
    out["configs"][f"data{d}_model{m}"] = {
        "n_devices": n_dev,
        "tokens_per_s": run.tokens_per_s,
        "wall_tokens_per_s": wall,
        "per_device_tokens_per_s": wall / n_dev,
        "scaling_efficiency":
            wall / base.counters["wall_tokens_per_s"] / n_dev,
        "shard_pages": run.counters["mesh"]["shard_pages"],
    }
print("MESH_LEG_JSON=" + json.dumps(out))
'''


def mesh_leg_economics(args):
    """Tensor-parallel serving on a CPU host-platform mesh: per-device
    tokens/s and scaling efficiency for (data, model) in {(2,1), (1,2),
    (2,2)}, with greedy tokens asserted bit-identical to the single-device
    engine inside the subprocess. On forced-host CPU devices all "devices"
    share one physical CPU, so efficiency well below 1 is expected — the
    leg exists so the trajectory is tracked where real accelerators will
    make it meaningful."""
    cfg = {"requests": min(args.requests, 4), "n_slots": args.n_slots,
           "prompt_len": min(args.prompt_len, 16),
           "new_tokens": min(args.new_tokens, 8),
           "arrival_every": args.arrival_every,
           "block_size": args.block_size,
           "max_len": 2 * (min(args.prompt_len, 16)
                           + min(args.new_tokens, 8))}
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_LEG_SCRIPT, json.dumps(cfg)],
        capture_output=True, text=True, env=env, timeout=1200)
    if proc.returncode != 0:
        raise SystemExit(f"mesh leg failed:\n{proc.stdout}\n{proc.stderr}")
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("MESH_LEG_JSON=")]
    assert line, proc.stdout
    payload = json.loads(line[0][len("MESH_LEG_JSON="):])
    for name, c in sorted(payload["configs"].items()):
        emit(f"serve_mesh_{name}_per_device_tok_s",
             c["per_device_tokens_per_s"],
             f"{c['n_devices']} host-platform devices, scaling eff "
             f"{c['scaling_efficiency']:.2f}, shard_pages "
             f"{c['shard_pages']}")
    print("# mesh leg: greedy tokens bit-identical to single-device for "
          + ", ".join(sorted(payload["configs"])))
    return payload


def attn_read_economics(paged, gather):
    """Per-decode-step attention KV HBM bytes read: the fused kernel's reads
    scale with live tokens, the gather path's with provisioned capacity.
    Fails unless fused is strictly lower while mean occupancy is <= 50%
    (the benchmark provisions 2x the request span, so it is)."""
    cf, cg = paged.counters, gather.counters
    assert cf["paged_attn"] == "fused" and cg["paged_attn"] == "gather"
    steps_f = max(paged.n_steps, 1)
    steps_g = max(gather.n_steps, 1)
    fused_step = cf["decode_attn_bytes_read"] / steps_f
    gather_step = cg["decode_attn_bytes_read"] / steps_g
    occupancy = (cf["decode_live_token_steps"]
                 / max(cf["decode_capacity_token_steps"], 1))
    emit("serve_decode_attn_bytes_per_step_fused", fused_step,
         f"live-token-proportional reads at {occupancy:.1%} mean occupancy")
    emit("serve_decode_attn_bytes_per_step_gather", gather_step,
         f"capacity-proportional: full block table materialized per layer")
    print(f"# decode attention KV reads/step: fused {fused_step:.0f} B vs "
          f"gather {gather_step:.0f} B ({fused_step / gather_step:.1%}) at "
          f"{occupancy:.1%} occupancy")
    if occupancy <= 0.5 and fused_step >= gather_step:
        raise SystemExit(
            f"fused-attention regression: {fused_step:.0f} attention bytes "
            f"per decode step not below the gather path's "
            f"{gather_step:.0f} at {occupancy:.1%} occupancy")


def chunked_prefill_economics(model, params, data, args):
    """Mixed-length stream + one deliberately long prompt through chunked
    prefill: compile economy (buckets vs distinct lengths) and the decode
    stall the chunk arbitration bounds."""
    chunk_len = max(args.prompt_len // 2, 8)
    lens = [max(args.prompt_len - (i % max(args.requests - 1, 1)), 1)
            for i in range(args.requests)]
    # the long prompt, clamped to what the synthetic stream can supply
    stream_len = int(data.batch_at(70_000)["tokens"].shape[1])
    lens[0] = min(2 * args.prompt_len, stream_len)
    reqs = [Request(rid=i,
                    tokens=np.asarray(
                        data.batch_at(70_000 + i)["tokens"][0, :lens[i]],
                        np.int32),
                    max_new_tokens=args.new_tokens,
                    arrival=i * args.arrival_every)
            for i in range(args.requests)]
    for r, n in zip(reqs, lens):
        assert r.prompt_len == n, (r.prompt_len, n)   # no silent truncation
    max_len = max(lens) + args.new_tokens
    eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                   max_len=max_len, paged=True,
                                   block_size=args.block_size,
                                   chunk_len=chunk_len, chunk_budget=1)
    eng.serve(params, [reqs[0]])                # warmup (compile)
    out = eng.serve(params, reqs)
    c = out.counters
    emit("serve_chunked_prefill_chunks", c["prefill_chunks"],
         f"chunk_len {chunk_len}, long prompt {lens[0]} tokens")
    emit("serve_chunked_decode_stall_p50_us",
         c.get("decode_stall_p50_s", 0.0) * 1e6,
         "prefill time injected between decode steps (median)")
    emit("serve_chunked_decode_stall_p99_us",
         c.get("decode_stall_p99_s", 0.0) * 1e6,
         f"longest stall run {c['max_decode_stall_run']} chunk steps "
         f"(budget 1)")
    emit("serve_prefill_compile_buckets", c["prefill_buckets"],
         f"vs {c['distinct_prompt_lens']} distinct prompt lengths")
    # parity guard: chunked + bucketed prefill must not change tokens
    ref = ServeEngine(model, donate=False)
    for r in reqs:
        want = np.asarray(ref.generate(
            params, {"tokens": jnp.asarray(r.tokens)[None]},
            max_new_tokens=args.new_tokens).tokens)[0]
        if not np.array_equal(out.results[r.rid].tokens, want):
            raise SystemExit(
                f"token-parity violation (chunked): rid {r.rid} diverged "
                f"from the one-shot reference")
    # acceptance: >= 2x fewer prefill compilations than distinct lengths
    # (only meaningful when the stream actually mixes lengths), and the
    # decode stall stays within the chunk budget
    if c["distinct_prompt_lens"] >= 4 \
            and 2 * c["prefill_buckets"] > c["distinct_prompt_lens"]:
        raise SystemExit(
            f"bucketing regression: {c['prefill_buckets']} compiled prefill "
            f"buckets for only {c['distinct_prompt_lens']} distinct lengths "
            f"(need >= 2x fewer)")
    if c["max_decode_stall_run"] > 1:
        raise SystemExit(
            f"stall regression: a decode slot waited "
            f"{c['max_decode_stall_run']} chunk steps (budget 1)")


if __name__ == "__main__":
    main()
