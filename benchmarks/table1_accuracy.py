"""Paper Table 1: accuracy / loss deltas across MP strategies.

For each strategy (IP-ET, IP-TT, IP-M, Random, Prefix) at a tau grid we
report, on held-out synthetic eval data: delta eval loss (ppl proxy) and
delta next-token accuracy vs the BF16 model — averaged over the tau grid,
mirroring the paper's averaging over configurations.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_bundle, bench_model, emit, eval_metrics
from repro.core.baselines import prefix_strategy, random_strategy

TAUS = (0.002, 0.005, 0.01, 0.02)


def main() -> None:
    model, params, data, _ = bench_model()
    bundle = bench_bundle()  # one calibration serves all 3 objectives x taus
    sens = bundle.sens
    names = [o.name for o in sens.ops]
    loss0, acc0 = eval_metrics(model, params, data)
    print(f"# bf16 reference: loss={loss0:.4f} acc={acc0:.4f}")
    print("strategy,tau,d_loss,d_acc,n_quantized")

    agg = {}
    for tau in TAUS:
        plans = {}
        for obj in ("ET", "TT", "M"):
            plans[f"IP-{obj}"] = bundle.solve(tau=tau, objective=obj).assignment
        budget = tau ** 2 * sens.loss_sq_mean
        plans["Random"] = random_strategy(names, sens, budget,
                                          seed=int(tau * 1e5))
        plans["Prefix"] = prefix_strategy(names, sens, budget)
        for strat, asg in plans.items():
            loss, acc = eval_metrics(model, params, data, assignment=asg)
            d_loss, d_acc = loss - loss0, acc - acc0
            print(f"{strat},{tau},{d_loss:+.4f},{d_acc:+.4f},{len(asg)}")
            agg.setdefault(strat, []).append((d_loss, d_acc))

    print("strategy,avg_d_loss,avg_d_acc")
    for strat, vals in agg.items():
        dl = np.mean([v[0] for v in vals])
        da = np.mean([v[1] for v in vals])
        print(f"{strat},{dl:+.4f},{da:+.4f}")
        emit(f"table1.{strat}.avg_d_loss", 0.0, f"{dl:+.5f}")


if __name__ == "__main__":
    main()
