"""Drive the multi-pod dry-run for one (arch x shape) cell and pretty-print
the memory/cost/roofline evidence.

    PYTHONPATH=src python examples/multipod_dryrun.py --arch qwen2p5_32b \
        --shape prefill_32k --mesh multi
"""
import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2p5_3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="single", choices=("single", "multi"))
    args = ap.parse_args()

    # dryrun must own the import order (forces 512 host devices pre-jax)
    from repro.launch.dryrun import run_cell
    rec = run_cell(args.arch, args.shape, args.mesh == "multi")
    rec.pop("traceback", None)
    roof = rec.get("roofline", {})
    roof.pop("meta", None)
    print(json.dumps(rec, indent=2, default=str))


if __name__ == "__main__":
    main()
