"""Full PTQ pipeline on a trained checkpoint: IP-ET / IP-TT / IP-M vs the
Random and Prefix baselines — the paper's Table-1 style comparison.

    PYTHONPATH=src python examples/ptq_pipeline.py [--tau 0.01]

Trains (or resumes) the small benchmark model, calibrates it once into a
``CalibrationBundle``, then solves each IP objective from that artifact and
reports, per strategy, the eval-loss delta, the predicted TPU-v5e time gain,
and the weight-memory gain of the produced MP configuration.
"""
import argparse

import numpy as np

from benchmarks.common import bench_bundle, bench_model, eval_metrics
from repro.core.baselines import prefix_strategy, random_strategy
from repro.core.pipeline import predicted_loss_mse
from repro.core.timegain import MemoryGainModel, RooflineGainModel
from repro.hw.profiles import TPU_V5E


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=float, default=0.01)
    args = ap.parse_args()

    model, params, data, train_loss = bench_model()
    # staged API: one calibration artifact, three cheap objective solves
    bundle = bench_bundle()
    sens = bundle.sens
    names = [o.name for o in sens.ops]
    op_index = {o.name: o for o in sens.ops}
    et = RooflineGainModel(TPU_V5E)
    mg = MemoryGainModel()

    loss0, acc0 = eval_metrics(model, params, data)
    print(f"bf16 reference: eval loss {loss0:.4f}, acc {acc0:.4f}\n")

    plans = {}
    for obj in ("ET", "TT", "M"):
        plans[f"IP-{obj}"] = bundle.solve(tau=args.tau, objective=obj).assignment
    budget = args.tau ** 2 * sens.loss_sq_mean
    plans["Random"] = random_strategy(names, sens, budget, seed=1)
    plans["Prefix"] = prefix_strategy(names, sens, budget)

    print(f"{'strategy':8s} {'d_loss':>9s} {'d_acc':>8s} {'pred_mse':>10s} "
          f"{'et_gain_us':>11s} {'mem_gain_MB':>11s} {'n_fp8':>5s}")
    for strat, asg in plans.items():
        loss, acc = eval_metrics(model, params, data, assignment=asg)
        etg = sum(et.op_time(op_index[n], "bf16") - et.op_time(op_index[n], f)
                  for n, f in asg.items())
        mgb = sum(mg.op_gain(op_index[n], f) for n, f in asg.items())
        print(f"{strat:8s} {loss-loss0:+9.4f} {acc-acc0:+8.4f} "
              f"{predicted_loss_mse(sens, asg):10.3e} {etg*1e6:11.2f} "
              f"{mgb/1e6:11.2f} {len(asg):5d}")


if __name__ == "__main__":
    main()
