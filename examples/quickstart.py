"""Quickstart: the paper's Algorithm 1 on a small model, end to end.

    PYTHONPATH=src python examples/quickstart.py

Steps: build model -> partition its graph into sequential sub-graphs ->
calibrate per-layer sensitivity (fwd+bwd) -> evaluate per-group gains ->
solve the IP -> print the MP plan and verify the loss-MSE contract.
"""
import jax
import numpy as np

from repro.core.graphs import build_graph
from repro.core.partition import partition_sequential
from repro.core.pipeline import AMPOptions, auto_mixed_precision
from repro.models.registry import get_model
from repro.quant.qops import QuantContext


def main():
    model = get_model("llama3_1b", smoke=True, n_layers=4)
    params = model.init(jax.random.key(0))

    # 1) partition (paper Alg. 2) — V1..V4 per block, exactly Fig. 6
    groups = partition_sequential(build_graph(model))
    print(f"partitioned into {len(groups)} sequential sub-graphs; first block:")
    for g in groups[:4]:
        print("  ", g)

    # 2+3+4) calibrate + gains + IP (paper Alg. 1)
    calib = [{"tokens": jax.random.randint(jax.random.key(i), (2, 64), 0, 512),
              "labels": jax.random.randint(jax.random.key(99 + i), (2, 64),
                                           0, 512)} for i in range(3)]
    # NOTE: objective "ET" (roofline time) at these tiny shapes correctly
    # judges most ops memory-bound (fp8 gains ~nothing on a roofline basis),
    # so the demo uses "TT" (MAC-based, eq. 24) to show the IP mechanics.
    opts = AMPOptions(tau=0.01, objective="TT")
    plan = auto_mixed_precision(model, params, calib, opts)

    print(f"\nMP plan: {plan.n_quantized}/{plan.meta['n_ops']} ops in FP8, "
          f"predicted loss-MSE {plan.predicted_loss_mse:.3e} "
          f"(budget {plan.budget:.3e}), predicted gain {plan.predicted_gain:.3e}s")
    fp8_ops = sorted(plan.assignment)[:8]
    print("first FP8 ops:", fp8_ops)

    # verify the contract: measured loss shift stays small
    ctx, ctx_mp = QuantContext(), QuantContext(mode="mp", mp=plan.assignment)
    errs = [(float(model.loss(params, b, ctx_mp))
             - float(model.loss(params, b, ctx))) ** 2 for b in calib]
    print(f"measured loss-MSE {np.mean(errs):.3e} <= budget "
          f"{plan.budget:.3e}: {np.mean(errs) <= plan.budget}")


if __name__ == "__main__":
    main()
