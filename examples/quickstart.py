"""Quickstart: the paper's Algorithm 1 on a small model, staged.

    PYTHONPATH=src python examples/quickstart.py

The expensive phase runs once — ``calibrate()`` partitions the graph into
sequential sub-graphs (Alg. 2), calibrates per-layer sensitivity (fwd+bwd,
Sec. 2.2), and tabulates per-group gains (Sec. 2.3) into a durable
``CalibrationBundle``. Every ``bundle.solve(tau=..., objective=...)`` after
that is a millisecond IP solve needing neither model nor params — including
from a bundle reloaded off disk.
"""
import os
import tempfile

import jax
import numpy as np

from repro.core.graphs import build_graph
from repro.core.partition import partition_sequential
from repro.core.pipeline import AMPOptions, CalibrationBundle, calibrate
from repro.models.registry import get_model
from repro.quant.qops import QuantContext


def main():
    model = get_model("llama3_1b", smoke=True, n_layers=4)
    params = model.init(jax.random.key(0))

    # 1) partition (paper Alg. 2) — V1..V4 per block, exactly Fig. 6
    groups = partition_sequential(build_graph(model))
    print(f"partitioned into {len(groups)} sequential sub-graphs; first block:")
    for g in groups[:4]:
        print("  ", g)

    # 2+3) calibrate: sensitivity + per-group gain tables, once
    calib = [{"tokens": jax.random.randint(jax.random.key(i), (2, 64), 0, 512),
              "labels": jax.random.randint(jax.random.key(99 + i), (2, 64),
                                           0, 512)} for i in range(3)]
    # NOTE: objective "ET" (roofline time) at these tiny shapes correctly
    # judges most ops memory-bound (fp8 gains ~nothing on a roofline basis),
    # so the demo uses "TT" (MAC-based, eq. 24) to show the IP mechanics.
    bundle = calibrate(model, params, calib,
                       AMPOptions(tau=0.01, objective="TT"))

    # 4) solve the IP — and re-solve at another tau without recalibrating
    plan = bundle.solve()                 # calibration-time (tau, objective)
    plan_loose = bundle.solve(tau=0.05)   # pure NumPy, milliseconds
    print(f"\nMP plan (tau=0.01): {plan.n_quantized}/{plan.meta['n_ops']} ops "
          f"in FP8, predicted loss-MSE {plan.predicted_loss_mse:.3e} "
          f"(budget {plan.budget:.3e}), predicted gain {plan.predicted_gain:.3e}s")
    print(f"re-solved at tau=0.05: {plan_loose.n_quantized} ops, "
          f"gain {plan_loose.predicted_gain:.3e}s")
    fp8_ops = sorted(plan.assignment)[:8]
    print("first FP8 ops:", fp8_ops)

    # the artifact is durable: save, reload, solve identically — no model
    path = os.path.join(tempfile.mkdtemp(), "bundle.json")
    bundle.save(path)
    replayed = CalibrationBundle.load(path).solve()
    print(f"saved -> {path}; reloaded solve identical: {replayed == plan}")

    # verify the contract: measured loss shift stays small
    ctx, ctx_mp = QuantContext(), QuantContext(mode="mp", mp=plan.assignment)
    errs = [(float(model.loss(params, b, ctx_mp))
             - float(model.loss(params, b, ctx))) ** 2 for b in calib]
    print(f"measured loss-MSE {np.mean(errs):.3e} <= budget "
          f"{plan.budget:.3e}: {np.mean(errs) <= plan.budget}")


if __name__ == "__main__":
    main()
