"""Continuous-batching serving demo: an MPPlan flows from the IP solver
straight into the engine, and a staggered request stream drains through a
paged KV-block pool (vLLM-style block tables; ``--dense-slots`` for the old
monolithic rings).

    PYTHONPATH=src python examples/serve_continuous.py \
        [--tau 0.01] [--n-slots 4] [--requests 8] [--new-tokens 12] \
        [--block-size 8] [--n-blocks 24] [--no-mp] [--sync] \
        [--chunk-len 16 --chunk-budget 1 --long-prompt-len 96] \
        [--paged-attn fused|gather] [--dump-tokens toks.json] \
        [--shared-prefix-len 16] [--no-prefix-cache] \
        [--priorities 0,1] [--expect-preemptions] \
        [--inject-faults 'nan_page@4;alloc_failure@6' --max-retries 2 \
         --expect-retried 1 --expect-failed 0] \
        [--mesh data=2,model=2]   # needs data*model devices, e.g.
                                  # XLA_FLAGS=--xla_force_host_platform_device_count=8

Pipeline shown here (the full plan->engine handoff):
  1. ``CalibrationBundle.solve`` runs the IP (here from the shared benchmark
     bundle) and returns an ``MPPlan``;
  2. ``ContinuousBatchingEngine(model, mp=plan)`` compiles quantized
     prefill/decode steps from the plan (``core.mpconfig.as_assignment``);
  3. requests with different prompts/arrival times share one decode batch,
     each cache slot advancing at its own sequence depth, KV blocks
     allocated as each prefill chunk lands / each sequence crosses a block
     boundary. Prefill is length-bucketed; ``--chunk-len`` additionally
     splits long prompts into chunks interleaved with decode steps
     (``--long-prompt-len`` makes request 0 deliberately long to show the
     bounded-stall interleave).

The drain is pipelined by default (the device runs ahead of the host; a
consumer thread lands token values — ``--sync`` keeps the legacy lockstep
loop that reads every step back before dispatching the next). Paged
engines also prefix-cache by default: ``--shared-prefix-len`` gives every
request the same leading tokens so followers skip the shared blocks
(``--no-prefix-cache`` to compare), and ``--priorities``/
``--expect-preemptions`` exercise priority-class preemption under a tight
``--n-blocks`` pool. Exits non-zero unless every request completes, the
continuous engine's greedy tokens exactly match the one-shot reference
(including preempted-and-resumed requests), AND — when chunking is on —
no decode slot ever stalled more than ``--chunk-budget`` chunk steps.
This is the contract the CI serve-smoke job enforces (including at the
seed-era divergence-report shape: 3 requests x 16-token prompts).

``--inject-faults`` arms the deterministic fault harness (NaN-poisoned KV
pages, allocation failures, step exceptions, ...): the drain must still
complete, 'retried' requests must match the one-shot reference bit for
bit (re-prefill containment), and 'failed' requests must return an exact
reference prefix. The CI fault-serve-smoke job diffs ``--dump-tokens``
between a faulted and a fault-free run — they must be identical as long
as every fault was contained within the retry budget.
"""
import argparse

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_bundle, bench_model
from repro.serve import (AdaptiveMPController, ContinuousBatchingEngine,
                         Request, ServeEngine)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=float, default=0.01)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--arrival-every", type=int, default=2)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--n-blocks", type=int, default=None)
    ap.add_argument("--chunk-len", type=int, default=None,
                    help="split prompts into prefill chunks of this many "
                         "tokens, interleaved with decode steps (paged only)")
    ap.add_argument("--chunk-budget", type=int, default=1,
                    help="max prefill chunk steps between decode steps")
    ap.add_argument("--long-prompt-len", type=int, default=None,
                    help="make request 0 this long to demo bounded-stall "
                         "chunked prefill")
    ap.add_argument("--shared-prefix-len", type=int, default=None,
                    help="give every request the same leading N tokens "
                         "(distinct tails): the prefix cache admits "
                         "followers against the first request's registered "
                         "blocks and prefills only the tails")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request prefix sharing (paged "
                         "engines enable it by default; CI diffs "
                         "--dump-tokens across the two runs)")
    ap.add_argument("--priorities", default=None,
                    help="comma-separated priority classes cycled over the "
                         "request stream, e.g. '0,1' (higher preempts "
                         "lower under block pressure)")
    ap.add_argument("--expect-preemptions", action="store_true",
                    help="exit non-zero unless the drain preempted at "
                         "least one request (CI tight-pool run)")
    ap.add_argument("--dense-slots", action="store_true",
                    help="monolithic per-slot rings instead of paged blocks")
    ap.add_argument("--paged-attn", default=None,
                    choices=("fused", "gather"),
                    help="paged decode attention: fused Pallas kernel "
                         "(default) vs the gather reference path")
    ap.add_argument("--dump-tokens", default=None,
                    help="write {rid: greedy tokens} json here (CI diffs "
                         "fused-vs-gather runs)")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh spec like 'data=2,model=2': "
                         "tensor-parallel steps over a device-sharded paged "
                         "KV pool; greedy tokens stay bit-identical to the "
                         "single-device engine (the CI mesh-serve-smoke job "
                         "diffs --dump-tokens across the two)")
    ap.add_argument("--adaptive-tau", type=float, default=None,
                    help="serve under load-adaptive MP: a tau ladder "
                         "starting here escalates to more aggressive plans "
                         "as the queue grows and restores as it drains. "
                         "Runs two arms: 'fixed' (the base plan, checked "
                         "against the one-shot reference as usual) and "
                         "'adaptive' (the controller-driven engine)")
    ap.add_argument("--adaptive-levels", type=int, default=3,
                    help="tau ladder depth; 1 pins the controller to the "
                         "base plan (it can never swap), the CI control arm")
    ap.add_argument("--adaptive-every", type=int, default=2,
                    help="controller evaluation cadence in engine ticks")
    ap.add_argument("--adaptive-dwell", type=int, default=4,
                    help="min ticks between plan swaps")
    ap.add_argument("--expect-adaptive-cycle", action="store_true",
                    help="exit non-zero unless the adaptive drain both "
                         "downshifted (escalated) and restored at least "
                         "once (CI bursty run)")
    ap.add_argument("--inject-faults", default=None,
                    help="deterministic fault schedule, e.g. "
                         "'nan_page@4;alloc_failure@6' (kind@step[,k=v...]; "
                         "specs ';'-separated — see repro.serve.FaultSpec). "
                         "Fault-affected requests relax the parity contract: "
                         "'retried' results must still match the one-shot "
                         "reference bit for bit, 'failed' results must be an "
                         "exact prefix of it")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="bounded per-request retries after a contained "
                         "fault before the request is marked failed")
    ap.add_argument("--expect-retried", type=int, default=None,
                    help="exit non-zero unless at least this many requests "
                         "finished with status 'retried' (CI fault-smoke)")
    ap.add_argument("--expect-failed", type=int, default=None,
                    help="exit non-zero unless exactly this many requests "
                         "finished with status 'failed'")
    ap.add_argument("--no-mp", action="store_true",
                    help="skip bundle calibration / MP plan (bf16 only; "
                         "fast path for CI smoke)")
    ap.add_argument("--sync", action="store_true",
                    help="lockstep drain (read every step's tokens before "
                         "the next step) instead of the pipelined default")
    args = ap.parse_args()

    model, params, data, _ = bench_model()
    from repro.launch.mesh import mesh_from_spec
    mesh = mesh_from_spec(args.mesh)
    if mesh is not None:
        print(f"serving mesh: {dict(mesh.shape)}")
    # each config: (tag, fixed MP plan or None, adaptive controller or None)
    if args.adaptive_tau is not None:
        assert not args.no_mp, "--adaptive-tau needs the MP bundle"
        ctrl = AdaptiveMPController.from_bundle(
            bench_bundle(), args.adaptive_tau,
            n_levels=args.adaptive_levels, objective="ET",
            every=args.adaptive_every, dwell=args.adaptive_dwell,
            queue_high=2, queue_low=0)
        base = ctrl.plan
        print(f"adaptive MP: tau ladder {[f'{t:g}' for t in ctrl.taus]} "
              f"(base plan quantizes {base.n_quantized} ops)\n")
        configs = [("fixed", base, None), ("adaptive", None, ctrl)]
    else:
        configs = [("bf16", None, None)]
        if not args.no_mp:
            plan = bench_bundle().solve(tau=args.tau, objective="ET")
            print(f"MP plan quantizes {plan.n_quantized}/{plan.meta['n_ops']} ops\n")
            configs.append(("mp-fp8", plan, None))

    lens = [args.prompt_len] * args.requests
    if args.long_prompt_len:
        lens[0] = args.long_prompt_len
    prios = [0] * args.requests
    if args.priorities:
        classes = [int(x) for x in args.priorities.split(",")]
        prios = [classes[i % len(classes)] for i in range(args.requests)]

    def prompt(i):
        toks = np.asarray(
            data.batch_at(50_000 + i)["tokens"][0, :lens[i]], np.int32)
        if args.shared_prefix_len:
            n = args.shared_prefix_len
            assert n < lens[i], (n, lens[i])
            # same base for everyone, request-distinct tail
            toks = np.concatenate([
                np.asarray(data.batch_at(50_000)["tokens"][0, :n], np.int32),
                toks[n:]])
        return toks

    reqs = [Request(rid=i, tokens=prompt(i), max_new_tokens=args.new_tokens,
                    arrival=i * args.arrival_every, priority=prios[i])
            for i in range(args.requests)]
    max_len = max(lens) + args.new_tokens

    outs = {}
    for tag, mp, ctrl in configs:
        injector = None
        if args.inject_faults:
            from repro.serve import FaultInjector
            injector = FaultInjector.parse(args.inject_faults)
        eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                       max_len=max_len, mp=mp,
                                       paged=not args.dense_slots,
                                       block_size=args.block_size,
                                       n_blocks=args.n_blocks,
                                       chunk_len=args.chunk_len,
                                       chunk_budget=args.chunk_budget,
                                       paged_attn=args.paged_attn,
                                       mesh=mesh,
                                       prefix_cache=(False
                                                     if args.no_prefix_cache
                                                     else None),
                                       adaptive=ctrl,
                                       faults=injector,
                                       max_retries=args.max_retries)
        eng.faults = None   # warmup must not consume the fault schedule
        eng.serve(params, [reqs[0]], sync=args.sync)   # warmup (compile)
        eng.faults = injector
        out = eng.serve(params, reqs, sync=args.sync)
        outs[tag] = out
        ttfts = sorted(r.ttft_s for r in out.results.values())
        print(f"{tag:8s} {out.n_steps:4d} decode steps   "
              f"{out.tokens_per_s:8.1f} tok/s   "
              f"TTFT p50 {ttfts[len(ttfts)//2]*1e3:7.2f} ms")
        c = out.counters
        print(f"{'':8s} drain: {'lockstep' if c['sync'] else 'pipelined'} "
              f"({c['host_blocked_s_per_step']*1e6:.1f} us/step "
              f"host-blocked, {c['n_readbacks']} readbacks, "
              f"device {c['steps_in_flight_peak']} steps ahead at peak)")
        if c.get("paged"):
            print(f"{'':8s} paged KV: {c['peak_blocks_in_use']}/"
                  f"{c['n_blocks'] - 1} blocks at peak (block_size "
                  f"{c['block_size']}), peak KV {c['peak_kv_bytes']/1e6:.2f} "
                  f"MB vs dense-slot {c['dense_kv_bytes']/1e6:.2f} MB, "
                  f"{c['blocked_admissions']} blocked admissions")
        print(f"{'':8s} prefill: {c['prefill_chunks']} chunk steps "
              f"({c['prefill_tokens']} prompt tokens) over "
              f"{c['prefill_buckets']} compile buckets "
              f"({c['distinct_prompt_lens']} distinct prompt lengths); "
              f"decode stalls: {c['decode_stall_steps']} chunk steps "
              f"mid-decode, longest run {c['max_decode_stall_run']}")
        if c.get("prefix_cache"):
            print(f"{'':8s} prefix cache: {c['prefix_hit_requests']} hit "
                  f"requests, {c['prefix_hit_tokens']} prompt tokens "
                  f"skipped ({c['prefix_hit_blocks']} shared blocks, "
                  f"{c['cow_forks']} COW forks)")
        if c.get("paged") and (args.priorities or c["preemptions"]):
            print(f"{'':8s} preemption: {c['preemptions']} evictions under "
                  f"block pressure ({c['blocked_admissions']} blocked "
                  f"admissions)")
        if "adaptive" in c:
            a = c["adaptive"]
            print(f"{'':8s} adaptive MP: {a['downshifts']} downshifts / "
                  f"{a['restores']} restores, final tau {a['final_tau']:g} "
                  f"(level {a['final_level']}), swaps at steps "
                  f"{[sw['step'] for sw in a['swaps']] or 'none'}")

        f = c.get("faults")
        if f and (f["seen"] or f["injected"]):
            print(f"{'':8s} faults: injected "
                  f"{dict(sorted(f['injected'].items())) or 'none'}, "
                  f"{f['contained']} contained / {f['retries']} retries / "
                  f"{f['failed']} failed, {f['quarantined_blocks']} blocks "
                  f"quarantined" + (", degraded fused->gather"
                                    if f["degraded_paged_attn"] else ""))

        # contract checks: completion + exact greedy parity vs one-shot
        # (the drain must deliver a result for EVERY request even under
        # injected faults — failed ones carry their partial tokens)
        missing = [r.rid for r in reqs if r.rid not in out.results]
        if missing:
            raise SystemExit(f"{tag}: requests never completed: {missing}")
        statuses = {r.rid: out.results[r.rid].status for r in reqs}
        n_retried = sum(1 for s in statuses.values() if s == "retried")
        n_failed = sum(1 for s in statuses.values() if s == "failed")
        if injector is not None:
            if not injector.fired:
                raise SystemExit(f"{tag}: --inject-faults given but no "
                                 f"fault ever fired (schedule beyond the "
                                 f"drain?)")
            bad = {r: s for r, s in statuses.items()
                   if s not in ("ok", "retried", "failed")}
            if bad:
                raise SystemExit(f"{tag}: unexpected result statuses {bad}")
        if args.expect_retried is not None and n_retried < args.expect_retried:
            raise SystemExit(f"{tag}: --expect-retried {args.expect_retried} "
                             f"but only {n_retried} requests were retried")
        if args.expect_failed is not None and n_failed != args.expect_failed:
            raise SystemExit(f"{tag}: --expect-failed {args.expect_failed} "
                             f"but {n_failed} requests failed")
        swapped = bool(out.counters.get("adaptive", {}).get("swaps"))
        if ctrl is not None and not swapped:
            # control arm: a controller that never fires must be
            # bit-identical to the plain fixed-plan engine
            for r in reqs:
                if not np.array_equal(out.results[r.rid].tokens,
                                      outs["fixed"].results[r.rid].tokens):
                    raise SystemExit(
                        f"{tag}: rid {r.rid} diverged from the fixed-tau "
                        f"arm although the controller never swapped plans")
            print(f"{'':8s} controller never fired: tokens bit-identical "
                  f"to the fixed-tau arm")
            if args.expect_adaptive_cycle:
                raise SystemExit(
                    f"{tag}: --expect-adaptive-cycle, but the controller "
                    f"never swapped plans (load not bursty enough?)")
        if swapped:
            # plans changed mid-drain: numerics are intentionally plan-
            # dependent, so the one-shot parity contract doesn't apply
            if args.expect_adaptive_cycle:
                a = out.counters["adaptive"]
                if not (a["downshifts"] >= 1 and a["restores"] >= 1):
                    raise SystemExit(
                        f"{tag}: --expect-adaptive-cycle, but the drain saw "
                        f"{a['downshifts']} downshifts / {a['restores']} "
                        f"restores (no full cycle)")
                print(f"{'':8s} adaptive cycle confirmed: "
                      f">=1 downshift and >=1 restore\n")
            continue
        # one batched generate per distinct prompt length (usually one
        # group, plus the --long-prompt-len outlier)
        ref_eng = ServeEngine(model, mp=mp if ctrl is None else ctrl.plan,
                              donate=False)
        by_len = {}
        for r in reqs:
            by_len.setdefault(len(r.tokens), []).append(r)
        for group in by_len.values():
            ref = ref_eng.generate(
                params,
                {"tokens": jnp.asarray(np.stack([r.tokens for r in group]))},
                max_new_tokens=args.new_tokens)
            ref_toks = np.asarray(ref.tokens)
            for j, r in enumerate(group):
                got = np.asarray(out.results[r.rid].tokens)
                if statuses[r.rid] == "failed":
                    # retry budget exhausted: the engine still returns the
                    # last-known-good tokens, an exact reference prefix
                    if not np.array_equal(got, ref_toks[j][:len(got)]):
                        raise SystemExit(
                            f"{tag}: failed rid {r.rid} returned tokens "
                            f"that are not a prefix of the fault-free "
                            f"reference — containment leaked bad values")
                    continue
                # ok AND retried results must be bit-identical: a retried
                # request re-prefills its prompt + tokens-so-far, so a
                # contained fault never changes what the user receives
                if not np.array_equal(got, ref_toks[j]):
                    raise SystemExit(
                        f"{tag}: rid {r.rid} ({statuses[r.rid]}) diverged "
                        f"from the one-shot reference — chunked/paged/"
                        f"continuous decode is broken")
        # the stall bound the chunk arbitration exists to enforce
        if args.chunk_len is not None \
                and c["max_decode_stall_run"] > args.chunk_budget:
            raise SystemExit(
                f"{tag}: a decode slot stalled "
                f"{c['max_decode_stall_run']} chunk steps "
                f"(> budget {args.chunk_budget})")
        if args.expect_preemptions and not c.get("preemptions"):
            raise SystemExit(
                f"{tag}: --expect-preemptions, but the drain never "
                f"preempted a request (pool not tight enough, or "
                f"priorities uniform)")
        print(f"{'':8s} all {len(reqs)} requests completed, greedy tokens "
              f"== one-shot reference\n")

    if args.dump_tokens:
        import json
        with open(args.dump_tokens, "w") as f:
            json.dump({tag: {str(r.rid): np.asarray(
                out.results[r.rid].tokens).tolist() for r in reqs}
                for tag, out in outs.items()},
                f, indent=0, sort_keys=True)
        print(f"greedy tokens written to {args.dump_tokens}")

    if "mp-fp8" in outs:
        agree = np.mean([
            np.mean(outs["bf16"].results[i].tokens
                    == outs["mp-fp8"].results[i].tokens)
            for i in range(args.requests)])
        print(f"greedy-token agreement bf16 vs mp: {agree:.2%}")
        print("(on-host quantization is simulated; wall-clock gains appear "
              "on accelerators with native FP8 throughput — see DESIGN.md)")


if __name__ == "__main__":
    main()
