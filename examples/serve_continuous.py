"""Continuous-batching serving demo: an MPPlan flows from the IP solver
straight into the engine, and a staggered request stream drains through a
fixed pool of cache slots.

    PYTHONPATH=src python examples/serve_continuous.py \
        [--tau 0.01] [--n-slots 4] [--requests 8] [--new-tokens 12]

Pipeline shown here (the full plan->engine handoff):
  1. ``CalibrationBundle.solve`` runs the IP (here from the shared benchmark
     bundle) and returns an ``MPPlan``;
  2. ``ContinuousBatchingEngine(model, mp=plan)`` compiles quantized
     prefill/decode steps from the plan (``core.mpconfig.as_assignment``);
  3. requests with different prompts/arrival times share one decode batch,
     each cache slot advancing at its own sequence depth.
"""
import argparse

import numpy as np

from benchmarks.common import bench_bundle, bench_model
from repro.serve import ContinuousBatchingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=float, default=0.01)
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=12)
    ap.add_argument("--arrival-every", type=int, default=2)
    args = ap.parse_args()

    model, params, data, _ = bench_model()
    plan = bench_bundle().solve(tau=args.tau, objective="ET")
    print(f"MP plan quantizes {plan.n_quantized}/{plan.meta['n_ops']} ops\n")

    rng = np.random.default_rng(11)
    reqs = [Request(rid=i,
                    tokens=np.asarray(
                        data.batch_at(50_000 + i)["tokens"][0,
                                                            :args.prompt_len],
                        np.int32),
                    max_new_tokens=args.new_tokens,
                    arrival=i * args.arrival_every)
            for i in range(args.requests)]
    max_len = args.prompt_len + args.new_tokens

    outs = {}
    for tag, mp in (("bf16", None), ("mp-fp8", plan)):
        eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                       max_len=max_len, mp=mp)
        eng.serve(params, [reqs[0]])          # warmup (compile)
        out = eng.serve(params, reqs)
        outs[tag] = out
        ttfts = sorted(r.ttft_s for r in out.results.values())
        print(f"{tag:8s} {out.n_steps:4d} decode steps   "
              f"{out.tokens_per_s:8.1f} tok/s   "
              f"TTFT p50 {ttfts[len(ttfts)//2]*1e3:7.2f} ms")

    agree = np.mean([
        np.mean(outs["bf16"].results[i].tokens == outs["mp-fp8"].results[i].tokens)
        for i in range(args.requests)])
    print(f"\ngreedy-token agreement bf16 vs mp: {agree:.2%}")
    print("(on-host quantization is simulated; wall-clock gains appear on "
          "accelerators with native FP8 throughput — see DESIGN.md)")


if __name__ == "__main__":
    main()
