"""Serve a model with batched requests under an MP configuration:
measures TTFT (the paper's metric) and decode throughput, BF16 vs IP-chosen
FP8 mixed precision.

    PYTHONPATH=src python examples/serve_mp.py [--tau 0.01] [--new-tokens 16]
"""
import argparse

import jax
import numpy as np

from benchmarks.common import bench_bundle, bench_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tau", type=float, default=0.01)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    model, params, data, _ = bench_model()
    plan = bench_bundle().solve(tau=args.tau, objective="ET")
    print(f"MP plan quantizes {plan.n_quantized}/{plan.meta['n_ops']} ops\n")

    prompt = {"tokens": data.batch_at(40_000)["tokens"][:args.batch,
                                                        :args.prompt_len]}
    results = {}
    for tag, mp in (("bf16", None), ("mp-fp8", plan.assignment)):
        eng = ServeEngine(model, mp=mp, donate=False)
        # warmup (compile)
        eng.generate(params, dict(prompt), max_new_tokens=2)
        out = eng.generate(params, dict(prompt), max_new_tokens=args.new_tokens)
        results[tag] = out
        print(f"{tag:8s} TTFT {out.ttft_s*1e3:8.2f} ms   "
              f"decode {out.tokens_per_s:8.1f} tok/s")

    a, b = results["bf16"].tokens, results["mp-fp8"].tokens
    agree = float(np.mean(np.asarray(a) == np.asarray(b)))
    print(f"\ngreedy-token agreement bf16 vs mp: {agree:.2%}")
    print("(on-host quantization is simulated; wall-clock gains appear on "
          "accelerators with native FP8 throughput — see DESIGN.md)")


if __name__ == "__main__":
    main()
