"""End-to-end training driver: train an LM on the synthetic stream with
checkpointing, auto-resume and the straggler watchdog.

    PYTHONPATH=src python examples/train_lm.py                 # ~4M, 150 steps
    PYTHONPATH=src python examples/train_lm.py --preset 100m   # ~100M params
    PYTHONPATH=src python examples/train_lm.py --arch mamba2_370m --smoke

Interrupt it mid-run and re-launch: it resumes from the last checkpoint and
reproduces the identical data stream (step-seeded).
"""
import argparse

import jax

from repro.configs.llama3_1b import bench_config
from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models.lm import LMConfig
from repro.models.registry import build_model, get_model
from repro.train.optim import OptConfig, select_optimizer
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~100M-param llama-style config (the deliverable-scale driver; slow on
    # this CPU container — the default preset shows the same path in minutes)
    "100m": dict(name="lm100m", n_layers=12, d_model=768, vocab_size=32000,
                 n_heads=12, n_kv_heads=4, d_head=64, d_ff=2048,
                 tie_embeddings=True, flash_min_seq=1 << 30, loss_chunk=256),
    "bench": None,  # the ~4M benchmark config
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="bench", choices=list(PRESETS))
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args()

    if args.arch:
        model = get_model(args.arch, smoke=args.smoke)
    elif PRESETS[args.preset] is None:
        model = build_model(bench_config())
    else:
        model = build_model(LMConfig(**PRESETS[args.preset]))
    print(f"model: {model.cfg.name}  params={model.n_params():,}")

    data = SyntheticLM(SyntheticConfig(vocab_size=model.cfg.vocab_size,
                                       batch=args.batch, seq_len=args.seq))
    opt = select_optimizer(model.n_params(),
                           OptConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=args.steps))
    mesh = make_local_mesh(1, 1)
    tr = Trainer(model, opt, mesh,
                 TrainerConfig(total_steps=args.steps, ckpt_every=50,
                               ckpt_dir=args.ckpt_dir, log_every=10,
                               metrics_path=f"{args.ckpt_dir}/metrics.jsonl"))
    params, _, last = tr.fit(data)
    print(f"final loss {last:.4f}; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
