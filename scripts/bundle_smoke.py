"""CI smoke for the staged AMP pipeline: calibrate a tiny model ONCE, save
the CalibrationBundle, and run a fig4-style tau sweep entirely from the
cached artifact.

    PYTHONPATH=src python scripts/bundle_smoke.py [--out DIR]

Asserts:
  * the second calibrate() call with the same cache is a pure cache hit
    (no sensitivity recalibration);
  * a reloaded bundle solves to plans identical to the in-memory ones;
  * predicted gain is monotone non-decreasing in tau and every plan
    respects its loss-MSE budget.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile

import jax

import repro.core.pipeline as pl
from repro.core.pipeline import AMPOptions, CalibrationBundle, calibrate
from repro.models.registry import get_model

# low end tight enough that the IP must leave sensitive ops at bf16
TAUS = (0.0001, 0.0003, 0.001, 0.01, 0.05)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="artifact dir (default: tmp)")
    args = ap.parse_args()
    out = args.out or tempfile.mkdtemp(prefix="bundle_smoke_")
    os.makedirs(out, exist_ok=True)
    path = os.path.join(out, "bundle.npz")

    model = get_model("llama3_1b", smoke=True, n_layers=2)
    params = model.init(jax.random.key(0))
    calib = [{"tokens": jax.random.randint(jax.random.key(i), (2, 32), 0, 512),
              "labels": jax.random.randint(jax.random.key(9 + i), (2, 32),
                                           0, 512)} for i in range(2)]
    opts = AMPOptions(tau=0.01, objective="TT")

    bundle = calibrate(model, params, calib, opts, cache=path)
    print(f"[smoke] calibrated {len(bundle.sens.ops)} ops -> {path} "
          f"({os.path.getsize(path)} bytes)")

    # calibration must run exactly once: the second call is a cache hit
    def refuse(*a, **kw):
        raise AssertionError("cache miss: sensitivity recalibration ran")

    orig = pl.calibrate_sensitivity
    pl.calibrate_sensitivity = refuse
    try:
        again = calibrate(model, params, calib, opts, cache=path)
    finally:
        pl.calibrate_sensitivity = orig
    print("[smoke] second calibrate() was a pure cache hit")

    # fig4-style tau sweep from the saved artifact only (no model needed)
    loaded = CalibrationBundle.load(path)
    plans = loaded.pareto(TAUS, objective="TT")
    print("tau,predicted_gain_s,predicted_loss_mse,n_quantized")
    for tau, plan in zip(TAUS, plans):
        print(f"{tau},{plan.predicted_gain:.6e},"
              f"{plan.predicted_loss_mse:.6e},{plan.n_quantized}")
        assert plan.predicted_loss_mse <= plan.budget * (1 + 1e-9), \
            (tau, plan.predicted_loss_mse, plan.budget)
        mem = again.solve(tau=tau, objective="TT")
        assert dataclasses.asdict(mem) == dataclasses.asdict(plan), \
            f"loaded-bundle plan differs from in-memory plan at tau={tau}"

    gains = [p.predicted_gain for p in plans]
    assert all(a <= b + 1e-15 for a, b in zip(gains, gains[1:])), \
        f"gain not monotone non-decreasing in tau: {gains}"
    print(f"[smoke] OK: gain monotone over {len(TAUS)} taus from one "
          f"calibration artifact")


if __name__ == "__main__":
    main()
