"""Perf hillclimb driver: hypothesis -> change -> re-measure (dry-run tier).

Two kinds of changes:
* pricing changes (MP format assignments): re-priced analytically via
  ``terms_under_assignment`` (compute + memory terms); collectives unchanged.
* structural changes (sharding rules, microbatching, cache dtype): re-lower
  the cell via ``run_cell`` with overrides and re-derive all three terms.

Usage:
  PYTHONPATH=src python scripts/hillclimb.py --cell qwen2p5_32b:prefill_32k
"""
import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import argparse     # noqa: E402
import json         # noqa: E402

import jax          # noqa: E402

from repro.analysis import report    # noqa: E402
from repro.analysis.analytic import terms_under_assignment  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.hw.profiles import TPU_V5E    # noqa: E402


def load_cell(arch, shape, mesh="pod16x16"):
    path = f"experiments/dryrun/{arch}__{shape}__{mesh}.json"
    return json.load(open(path))


def show(tag, terms):
    dom = max(("compute", "memory", "collective"),
              key=lambda k: terms[f"t_{k}"])
    print(f"{tag:44s} C={terms['t_compute']:.3e} M={terms['t_memory']:.3e} "
          f"X={terms['t_collective']:.3e}  dom={dom}")
    return terms


def price_mp(rec, assignment, label):
    """Re-price compute/memory under an MP assignment; collectives kept."""
    base = report.refine(rec)
    ana = report._analytic(rec["arch"], rec["shape"])
    kind = SHAPES[rec["shape"]].kind
    t = terms_under_assignment(ana, kind, rec["roofline"]["chips"], TPU_V5E,
                               assignment)
    return show(label, {**base, **t})


def relower(arch, shape, overrides, label, mp=None):
    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, False, overrides=overrides, mp_assignment=mp)
    jax.clear_caches()
    if rec["status"] != "ok":
        print(label, "FAILED:", rec["reason"][:200])
        return None, rec
    return show(label, report.refine(rec)), rec


def all_fp8(rec, linear_only=False):
    ana = report._analytic(rec["arch"], rec["shape"])
    return {o["name"]: "fp8_e4m3" for o in ana["ops"]
            if (o["kind"] == "linear" or not linear_only)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)  # arch:shape
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    rec = load_cell(arch, shape)
    show("baseline (bf16, paper-faithful shardings)", report.refine(rec))
    price_mp(rec, all_fp8(rec), "paper IP all-FP8 (priced)")


if __name__ == "__main__":
    main()
