# Hillclimb record (EXPERIMENTS.md SPerf) — re-runnable:
# PYTHONPATH=src python scripts/<this file>
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, jax
from repro.analysis import report
from repro.analysis.analytic import terms_under_assignment
from repro.configs.shapes import SHAPES
from repro.hw.profiles import TPU_V5E
from repro.distributed import sharding as shd

ARCH, SHAPE = "qwen2p5_32b", "prefill_32k"
rec = json.load(open(f"experiments/dryrun/{ARCH}__{SHAPE}__pod16x16.json"))
base = report.refine(rec)
def show(tag, t):
    dom = max(("compute","memory","collective"), key=lambda k: t[f"t_{k}"])
    tot = max(t["t_compute"], t["t_memory"], t["t_collective"])
    print(f"{tag:52s} C={t['t_compute']:.3f} M={t['t_memory']:.3f} X={t['t_collective']:.3f} dom={dom}")
show("A0 baseline bf16 + FSDP shardings", base)

ana = report._analytic(ARCH, SHAPE)
fp8 = {o["name"]: "fp8_e4m3" for o in ana["ops"]}
t1 = terms_under_assignment(ana, "prefill", 256, TPU_V5E, fp8)
show("A1 paper IP all-FP8 (unfused requant, priced)", {**base, **t1})
t2 = terms_under_assignment(ana, "prefill", 256, TPU_V5E, fp8, fused_quant=True)
show("A2 + fused quantize epilogue (priced)", {**base, **t2})

# A3: structural — drop FSDP at inference (weights fit TP-only: ~4GB/dev)
from repro.launch.dryrun import run_cell
rec3 = run_cell(ARCH, SHAPE, False, overrides={"rules": shd.DEFAULT_RULES})
if rec3["status"] == "ok":
    r3 = report.refine(rec3)
    show("A3 no-FSDP (TP-only weights) re-lowered", r3)
    print("   mem/dev GB:", rec3["memory_analysis"]["peak_estimate_bytes"]/1e9)
    json.dump(rec3, open("experiments/perf/A3_qwen32b_prefill_nofsdp.json","w"), indent=2)
    t4 = terms_under_assignment(ana, "prefill", 256, TPU_V5E, fp8, fused_quant=True)
    show("A4 = A3 + A2 combined", {**r3, **t4})
else:
    print("A3 failed:", rec3["reason"][:200])
