# Hillclimb record (EXPERIMENTS.md SPerf) — re-runnable:
# PYTHONPATH=src python scripts/<this file>
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, jax
from repro.analysis import report
from repro.analysis.analytic import terms_under_assignment
from repro.hw.profiles import TPU_V5E
from repro.distributed import sharding as shd
from repro.launch.dryrun import run_cell

ARCH, SHAPE = "qwen2p5_32b", "train_4k"
rec = json.load(open(f"experiments/dryrun/{ARCH}__{SHAPE}__pod16x16.json"))
base = report.refine(rec)
def show(tag, t):
    dom = max(("compute","memory","collective"), key=lambda k: t[f"t_{k}"])
    print(f"{tag:56s} C={t['t_compute']:.3f} M={t['t_memory']:.3f} X={t['t_collective']:.3f} dom={dom}")
show("B0 baseline bf16 FSDP micro4", base)

# B1: fewer microbatches => FSDP regather/AR traffic scales with micro count.
rec1 = run_cell(ARCH, SHAPE, False, overrides={"n_microbatches": 2})
if rec1["status"] == "ok":
    r1 = report.refine(rec1)
    show("B1 micro4->micro2 re-lowered", r1)
    print("   mem/dev GB:", rec1["memory_analysis"]["peak_estimate_bytes"]/1e9)
    json.dump(rec1, open("experiments/perf/B1_qwen32b_train_micro2.json","w"), indent=2)
jax.clear_caches()

# B2: no-FSDP (ZeRO-1 only): kills embed-contraction ARs + weight gathers;
# keeps grad AR. Memory risk: params+grads replicated over data.
rec2 = run_cell(ARCH, SHAPE, False, overrides={"rules": shd.DEFAULT_RULES,
                                               "n_microbatches": 4})
if rec2["status"] == "ok":
    r2 = report.refine(rec2)
    show("B2 no-FSDP (ZeRO-1) micro4 re-lowered", r2)
    print("   mem/dev GB:", rec2["memory_analysis"]["peak_estimate_bytes"]/1e9)
    json.dump(rec2, open("experiments/perf/B2_qwen32b_train_nofsdp.json","w"), indent=2)
