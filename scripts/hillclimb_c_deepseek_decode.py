# Hillclimb record (EXPERIMENTS.md SPerf) — re-runnable:
# PYTHONPATH=src python scripts/<this file>
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, jax
from repro.analysis import report
from repro.analysis.analytic import analytic_costs, terms_under_assignment
from repro.hw.profiles import TPU_V5E
from repro.launch.dryrun import run_cell

ARCH, SHAPE = "deepseek_v3_671b", "decode_32k"
def show(tag, t):
    dom = max(("compute","memory","collective"), key=lambda k: t[f"t_{k}"])
    print(f"{tag:56s} C={t['t_compute']:.4f} M={t['t_memory']:.4f} X={t['t_collective']:.4f} dom={dom}")

rec3 = run_cell(ARCH, SHAPE, False, overrides={"mla_absorb_decode": True})
if rec3["status"] == "ok":
    ana1 = analytic_costs(ARCH, SHAPE, overrides={"mla_absorb_decode": True})
    t = terms_under_assignment(ana1, "decode", rec3["roofline"]["chips"], TPU_V5E)
    r3 = report.refine(rec3); r3.update(t)
    show("C3 absorbed-MLA + seq-sharded latent cache", r3)
    print("   mem/dev GB:", rec3["memory_analysis"]["peak_estimate_bytes"]/1e9)
    json.dump(rec3, open("experiments/perf/C3_deepseek_decode_absorb_seqshard.json","w"), indent=2)
    jax.clear_caches()
    rec4 = run_cell(ARCH, SHAPE, False, overrides={"mla_absorb_decode": True,
                                                   "param_dtype": "fp8_e4m3"})
    if rec4["status"] == "ok":
        fp8_lin = {o["name"]: "fp8_e4m3" for o in ana1["ops"] if o["kind"] == "linear"}
        t4 = terms_under_assignment(ana1, "decode", 256, TPU_V5E, fp8_lin, fused_quant=True)
        r4 = report.refine(rec4); r4.update(t4)
        show("C4 + fp8 weights (IP-M) re-lowered", r4)
        print("   mem/dev GB:", rec4["memory_analysis"]["peak_estimate_bytes"]/1e9)
        json.dump(rec4, open("experiments/perf/C4_deepseek_decode_full.json","w"), indent=2)
    else:
        print("C4 failed:", rec4["reason"][:150])
else:
    print("C3 failed:", rec3["reason"][:300])
