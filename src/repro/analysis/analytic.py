"""Analytic FLOP/byte accounting from the quantizable-op registry.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — scanned models
(layer scans, microbatch scans, flash/loss chunk scans) under-report by the
trip count, which would make the roofline table nonsense. Instead we trace a
*counting twin* of the model (unrolled layers, un-chunked loss/MoE, reference
attention) with ``jax.eval_shape`` — no allocation, exact global shapes — and
sum MACs/bytes over every registered op. Backward = 2x forward FLOPs
(standard); optimizer traffic adds 16 bytes/param (p, g, mu, nu rw amortized).

Elementwise/norm traffic is not counted (matmul-centric accounting; noted in
EXPERIMENTS.md — it underestimates the memory term by ~10-20% for dense
models, more for SSM).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeCell, input_specs
from repro.models.encdec import EncDec
from repro.models.lm import LM
from repro.models.registry import build_model, get_config
from repro.quant.qops import QuantContext

__all__ = ["counting_twin", "analytic_costs"]

_BYTES = 2.0  # bf16 operand/output bytes


def counting_twin(arch: str, cell: ShapeCell, overrides=None):
    """Full-size config reshaped so every op registers exactly once with
    global shapes."""
    ov = dict(scan_layers=False, remat=False, flash_min_seq=1 << 30,
              loss_chunk=cell.seq_len, **(overrides or {}))
    cfg = get_config(arch)
    if getattr(cfg, "moe", None) is not None:
        tokens = cell.global_batch * cell.seq_len
        ov["moe"] = dataclasses.replace(cfg.moe, token_chunk=max(tokens, 1))
    fields = {f.name for f in dataclasses.fields(cfg)}
    ov = {k: v for k, v in ov.items() if k in fields}
    return build_model(get_config(arch, **ov))


def _trace_ops(model, cell: ShapeCell) -> list:
    registry: list = []
    ctx = QuantContext(registry=registry)
    ins = input_specs(model, cell)
    if cell.kind == "train":
        jax.eval_shape(lambda p, b: model.loss(p, b, ctx),
                       model.abstract_params(), ins)
    elif cell.kind == "prefill":
        caches = _abstract_caches(model, cell)
        if isinstance(model, EncDec):
            jax.eval_shape(lambda p, c, b: model.prefill(
                p, b["frames"], b["tokens"], c, ctx),
                model.abstract_params(), caches, ins)
        else:
            jax.eval_shape(lambda p, c, b: model.prefill(
                p, b["tokens"], c, ctx,
                prefix_embeds=b.get("prefix_embeds")),
                model.abstract_params(), caches, ins)
    else:
        caches = _abstract_caches(model, cell)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        jax.eval_shape(lambda p, c, t, q: model.decode_step(p, t, q, c, ctx),
                       model.abstract_params(), caches, ins["token"], pos)
    # dedupe exact duplicates (e.g. whisper k/v projections traced both in
    # cross-attention and in the decode-cache precompute)
    seen, out = set(), []
    for op in registry:
        key = (op.name, op.lhs_shape, op.rhs_shape)
        if key not in seen:
            seen.add(key)
            out.append(op)
    return out


def _abstract_caches(model, cell: ShapeCell):
    if isinstance(model, EncDec):
        specs = model.cache_specs(cell.global_batch, cell.seq_len,
                                  enc_len=cell.seq_len)
        flat = {k: jax.ShapeDtypeStruct(s.shape, s.dtype)
                for k, s in specs.items()}
        caches = {}
        for key, v in flat.items():
            layer, leaf = key.rsplit("/", 1)
            caches.setdefault(layer, {})[leaf] = v
        return caches
    return model.init_cache(cell.global_batch, cell.seq_len, abstract=True)


def analytic_costs(arch: str, shape_name: str, overrides=None) -> dict:
    """Global analytic costs for one cell: flops, bytes, param traffic.

    Also returns a compact per-op table so the perf loop can re-price the
    terms under an MP assignment without re-tracing.
    """
    cell = SHAPES[shape_name]
    model = counting_twin(arch, cell, overrides)
    ops = _trace_ops(model, cell)
    fwd_flops = sum(2.0 * op.macs for op in ops)
    fwd_bytes = sum(_BYTES * (math.prod(op.lhs_shape)
                              + math.prod(op.rhs_shape)
                              + math.prod(op.out_shape)) for op in ops)
    n_params = sum(math.prod(s.shape) for s in model.param_specs().values())
    if cell.kind == "train":
        flops = 3.0 * fwd_flops
        byts = 3.0 * fwd_bytes + 16.0 * n_params
    else:
        flops = fwd_flops
        byts = fwd_bytes
    op_table = [
        {"name": op.name, "kind": op.kind, "macs": op.macs,
         "lhs": math.prod(op.lhs_shape), "rhs": math.prod(op.rhs_shape),
         "out": math.prod(op.out_shape)} for op in ops]
    return {"flops": flops, "bytes": byts, "n_ops": len(ops),
            "n_params": n_params, "fwd_flops": fwd_flops, "ops": op_table}


def terms_under_assignment(ana: dict, cell_kind: str, chips: int, hw,
                           assignment=None, ref: str = "bf16",
                           fused_quant: bool = False) -> dict:
    """Re-price compute/memory roofline terms under an op->format map.

    Quantized ops run at the format's MXU rate; their GEMM operands move at
    the format's byte width. Activation operands additionally pay a runtime
    requant pass (read ref + write fmt) UNLESS ``fused_quant`` — the
    quantize-in-producer-epilogue optimization (kernels/quant_cast fused, or
    the mp_attention kernel quantizing probs in-register). Collectives are
    format-independent here (activations cross the wire in bf16).
    """
    from repro.quant.formats import get_format
    assignment = assignment or {}
    ref_b = get_format(ref).bytes
    t_c = t_m_bytes = 0.0
    for op in ana["ops"]:
        fmt_name = assignment.get(op["name"], ref)
        fmt = get_format(fmt_name)
        t_c += 2.0 * op["macs"] / hw.flops(fmt_name)
        byts = (op["lhs"] + op["rhs"]) * fmt.bytes + op["out"] * ref_b
        if fmt.is_quantized and not fused_quant:
            act = op["lhs"] if op["kind"] == "linear" else op["lhs"] + op["rhs"]
            byts += act * (ref_b + fmt.bytes)  # runtime requant pass
        t_m_bytes += byts
    mult = 3.0 if cell_kind == "train" else 1.0
    t_m_bytes = t_m_bytes * mult + (16.0 * ana["n_params"]
                                    if cell_kind == "train" else 0.0)
    return {"t_compute": t_c * mult / chips,
            "t_memory": t_m_bytes / chips / hw.hbm_bw}
