"""Parameter accounting + MODEL_FLOPS references for the roofline table.

MODEL_FLOPS (the "useful" flops of a cell):
* train   : 6 * N_active_nonembed * tokens    (fwd 2N + bwd 4N)
* prefill : 2 * N_active_nonembed * tokens
* decode  : 2 * N_active_nonembed * batch     (one token per sequence)

MoE: routed experts contribute top_k/n_experts of their params to N_active
(shared experts fully). Embedding gathers are excluded; the LM head matmul
is included (it is a real GEMM).
"""
from __future__ import annotations

import math

from repro.configs.shapes import ShapeCell

__all__ = ["param_stats", "model_flops"]


def param_stats(model) -> dict:
    specs = model.param_specs()
    cfg = model.cfg
    total = active = embed = 0
    moe = getattr(cfg, "moe", None)
    n_layers_factor = 1
    for path, ps in specs.items():
        n = math.prod(ps.shape)
        total += n
        is_embed = path.startswith("embed/")
        is_head = path.startswith("lm_head")
        if is_embed:
            embed += n
            continue  # gather, not a GEMM
        if moe is not None and "/experts/" in path:
            active += n * moe.top_k / moe.n_experts
        else:
            active += n
        if is_head and getattr(cfg, "tie_embeddings", False):
            pass
    # tied embeddings: the head GEMM uses the embed matrix — count it once
    if getattr(cfg, "tie_embeddings", False) or not any(
            p.startswith("lm_head") for p in specs):
        head_spec = specs.get("embed/w")
        if head_spec is not None:
            active += math.prod(head_spec.shape)
    return {"total": int(total), "active": float(active), "embed": int(embed)}


def model_flops(model, cell: ShapeCell) -> float:
    stats = param_stats(model)
    n = stats["active"]
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch  # decode: one token per sequence
