"""Assemble EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSONs.

Roofline terms are *refined* here rather than taken raw from
``cost_analysis``: XLA counts each while-loop body once, so scanned models
under-report FLOPs/bytes by their trip counts. The refined pipeline uses
exact analytic FLOPs/bytes from the counting-twin op registry
(``analysis.analytic``) and rescales the HLO-parsed collective bytes by the
measured undercount factor M = flops_analytic / flops_hlo_total (collectives
live inside the same loops as the compute they serve). Methodology recorded
in EXPERIMENTS.md.
"""
from __future__ import annotations

import glob
import json
import os

from repro.hw.profiles import TPU_V5E

__all__ = ["load_records", "refine", "roofline_table", "dryrun_table"]

ANALYTIC_CACHE = "experiments/analytic"


def load_records(dryrun_dir: str = "experiments/dryrun") -> list:
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def _analytic(arch: str, shape: str) -> dict:
    os.makedirs(ANALYTIC_CACHE, exist_ok=True)
    path = os.path.join(ANALYTIC_CACHE, f"{arch}__{shape}.json")
    if os.path.exists(path):
        return json.load(open(path))
    from repro.analysis.analytic import analytic_costs
    c = analytic_costs(arch, shape)
    with open(path, "w") as f:
        json.dump(c, f)
    return c


def _layers_of(arch: str) -> int:
    from repro.models.registry import get_config
    cfg = get_config(arch)
    return cfg.n_layers


def refine(rec: dict, hw=TPU_V5E) -> dict:
    """Refined three-term roofline for one ok-record."""
    roof = rec["roofline"]
    chips = roof["chips"]
    ana = _analytic(rec["arch"], rec["shape"])
    flops_hlo_total = max(roof["flops_per_device"] * chips, 1.0)
    M = max(ana["flops"] / flops_hlo_total, 1.0)
    # collectives live at per-layer (and per-microbatch) loop depth; the
    # flops multiplier additionally includes flash/loss-chunk inner loops,
    # so cap the collective multiplier by the structural trip product
    M_coll = min(M, _layers_of(rec["arch"]) * rec.get("n_microbatches", 1))
    t_c = (ana["flops"] / chips) / hw.flops("bf16")
    t_m = (ana["bytes"] / chips) / hw.hbm_bw
    split = rec.get("collective_split")
    if split is not None:
        coll_bytes = split["toplevel"] + split["inloop"] * M_coll
    else:  # old record: scale everything (over-estimates top-level comms)
        coll_bytes = roof["collective_bytes_per_device"] * M_coll
    t_x = coll_bytes / hw.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bott = max(terms, key=terms.get)
    t_ideal = (roof["model_flops"] / chips) / hw.flops("bf16")
    t_dom = max(terms.values())
    return {
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "bottleneck": bott, "loop_multiplier": M, "coll_multiplier": M_coll,
        "model_flops": roof["model_flops"],
        "useful_ratio": roof["model_flops"] / ana["flops"],
        "peak_fraction": (t_ideal / t_dom) if t_dom > 0 else 0.0,
        "analytic_flops": ana["flops"], "analytic_bytes": ana["bytes"],
    }


def _fmt_t(x: float) -> str:
    return f"{x:.2e}"


LEVERS = {
    ("memory", "decode"): "fp8 KV cache + fp8 weights (IP-M)",
    ("memory", "prefill"): "fp8 MP execution (paper) halves GEMM bytes",
    ("memory", "train"): "fp8 matmul residency; tune remat_group",
    ("collective", "train"): "overlap reduce-scatter w/ bwd; fp8 grads",
    ("collective", "prefill"): "reshard qkv to cut all-gathers",
    ("collective", "decode"): "replicate small weights (skip gathers)",
    ("compute", "train"): "fp8 MXU execution (the paper's MP)",
    ("compute", "prefill"): "fp8 MXU execution (the paper's MP)",
    ("compute", "decode"): "fp8 MXU execution (the paper's MP)",
}


def roofline_table(recs: list, mesh: str = "pod16x16") -> str:
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| MODEL_FLOPS | useful | peak frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | skip: "
                         f"{r['reason'][:44]} | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR |||||||||")
            continue
        roof = refine(r)
        kind = ("train" if "train" in r["shape"]
                else "decode" if ("decode" in r["shape"] or "500k" in r["shape"])
                else "prefill")
        lever = LEVERS.get((roof["bottleneck"], kind), "—")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(roof['t_compute'])} "
            f"| {_fmt_t(roof['t_memory'])} | {_fmt_t(roof['t_collective'])} "
            f"| {roof['bottleneck']} | {roof['model_flops']:.2e} "
            f"| {roof['useful_ratio']:.2f} | {roof['peak_fraction']:.3f} "
            f"| {lever} |")
    return "\n".join(lines)


def dryrun_table(recs: list) -> str:
    lines = [
        "| arch | shape | mesh | status | mem/dev GB | fits v5e-16G | fsdp "
        "| kv fp8 | opt | compile s | collectives GB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | skip "
                         f"({r['reason'][:40]}) | — | — | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"ERROR | — | — | — | — | — | — | — |")
            continue
        mem = r["memory_analysis"].get("peak_estimate_bytes", 0) / 1e9
        coll = r["roofline"]["collective_bytes_per_device"] / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {mem:.2f} "
            f"| {'yes' if mem <= 16 else 'NO'} | {r.get('fsdp', False)} "
            f"| {r.get('kv_cache_dtype', '—')} | {r.get('optimizer', '—')} "
            f"| {r.get('compile_s', 0)} | {coll:.2f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    recs = load_records()
    print("## Dry-run\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single pod, 256 chips)\n")
    print(roofline_table(recs))
