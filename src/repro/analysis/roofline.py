"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (spec'd formulas):

    compute    = HLO_FLOPs        / (chips x peak_FLOP/s)
    memory     = HLO_bytes        / (chips x HBM_bw)
    collective = collective_bytes / (chips x link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``; collective
bytes are parsed from the post-SPMD HLO text (not present in cost_analysis),
summing operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops. ``cost_analysis``/HLO text are
*per-partition* on SPMD executables, so totals are (per-device value x
chips); the chips in numerator and denominator cancel — we report the
per-device value divided by per-chip peak directly.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.hw.profiles import TPU_V5E, HWProfile

__all__ = ["parse_collective_bytes", "RooflineReport", "analyze"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like  bf16[4096,1024]{1,0}  possibly inside tuples
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|[a-z0-9]+\[[^\]]*\][^\s]*)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")[\s(]", )


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\([^)]*(?:\)|$)")
_CALL_RE = re.compile(r"(?:body|condition|calls|to_apply)=%?([\w.\-]+)")
_WHILE_BODY_RE = re.compile(r"\bwhile\(.*?body=%?([\w.\-]+)", re.S)


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes per collective kind (per-partition).

    Also splits bytes into ``toplevel`` vs ``inloop``: XLA's cost/HLO views
    count while-loop bodies once, so collectives inside loop bodies must be
    scaled by the loop trip product (the caller knows it as the analytic /
    HLO FLOP ratio) while top-level ones must not.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}

    # split the module into computation blocks; headerless text (unit tests,
    # fragments) accumulates under a synthetic top-level computation
    comps: dict = {"__top__": {"lines": [], "entry": True}}
    current = "__top__"
    for line in hlo_text.splitlines():
        if not line.startswith(" ") and "{" in line and "(" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            current = m.group(1) if m else "__top__"
            comps.setdefault(current, {"lines": [], "entry": "ENTRY" in line})
        comps[current]["lines"].append(line)

    # call graph: computation -> called computations; find loop bodies
    called_by_while: set = set()
    calls: dict = {}
    for name, info in comps.items():
        body = "\n".join(info["lines"])
        calls[name] = set(_CALL_RE.findall(body))
        for m in re.finditer(r"\bwhile\([^)]*\)[^\n]*", body):
            for b in _CALL_RE.findall(m.group(0)):
                called_by_while.add(b)

    # computations transitively reachable from a while body are "in loop"
    in_loop: set = set()
    frontier = list(called_by_while)
    while frontier:
        n = frontier.pop()
        if n in in_loop:
            continue
        in_loop.add(n)
        frontier.extend(calls.get(n, ()))

    top = loop = 0.0
    for name, info in comps.items():
        scope_in_loop = name in in_loop
        for line in info["lines"]:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            type_str, kind = m.group(1), m.group(2)
            b = _shape_bytes(type_str)
            out[kind] += b
            counts[kind] += 1
            if scope_in_loop:
                loop += b
            else:
                top += b
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["toplevel"] = top
    out["inloop"] = loop
    out["counts"] = counts
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float            # 6*N*D train / 2*N*D inference (total)
    useful_ratio: float           # model_flops / (HLO flops x chips)
    peak_fraction: float          # t_bound(model) / t_dominant
    memory_per_device: dict
    meta: dict

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def analyze(*, arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_stats: Optional[dict] = None,
            hw: HWProfile = TPU_V5E, compute_fmt: str = "bf16",
            meta: Optional[dict] = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = parse_collective_bytes(hlo_text)

    t_c = flops / hw.flops(compute_fmt)
    t_m = byts / hw.hbm_bw
    t_x = coll["total"] / hw.ici_bw
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)

    total_flops = flops * chips
    useful = model_flops / total_flops if total_flops else 0.0
    # fraction of the dominant-term time that ideal (model-flops) compute
    # would need: how close the cell is to its roofline
    t_ideal = (model_flops / chips) / hw.flops(compute_fmt)
    t_dom = max(terms.values())
    peak_fraction = t_ideal / t_dom if t_dom > 0 else 0.0

    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes_per_device=coll["total"],
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck, model_flops=model_flops,
        useful_ratio=useful, peak_fraction=peak_fraction,
        memory_per_device=memory_stats or {},
        meta={**(meta or {}), "collectives": coll},
    )
