"""Fault-tolerant checkpointing.

Design for 1000+ node operation (scaled to this container's single process):

* **Atomicity** — checkpoints are staged into ``step_<N>.tmp`` and renamed
  only after every array and the manifest (with per-array SHA-256 digests)
  are fsynced. A crash mid-save never corrupts the latest checkpoint.
* **Mesh-agnostic restore** — arrays are stored as full logical tensors plus
  the param-path; the restorer re-shards onto *whatever mesh the new job
  has* (elastic rescale = restore onto a different mesh, nothing else).
  On a real multi-host deployment the same layout maps to per-host shard
  files keyed by (path, shard-index); the manifest format already carries
  the shape/dtype needed to stitch them.
* **Keep-N GC** + corrupted-checkpoint quarantine: restore walks backwards
  until a digest-valid checkpoint is found.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Optional

import jax
import ml_dtypes
import numpy as np

from repro.nn.spec import flatten_paths, tree_from_flat

__all__ = ["CheckpointManager"]

_MANIFEST = "manifest.json"


def _digest(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3):
        self.dir = directory
        self.keep_n = keep_n
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def all_steps(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: dict, extra: Optional[dict] = None) -> str:
        """Blocking save; atomic via tmp-dir + rename."""
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = flatten_paths(tree)
        manifest = {"step": step, "time": time.time(), "extra": extra or {},
                    "arrays": {}}
        arrays = {}
        for path, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            key = path.replace("/", "\x1f")
            arrays[key] = arr
            manifest["arrays"][path] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "digest": _digest(arr),
            }
        npz_path = os.path.join(tmp, "arrays.npz")
        with open(npz_path, "wb") as f:
            np.savez(f, **arrays)
            f.flush()
            os.fsync(f.fileno())
        man_path = os.path.join(tmp, _MANIFEST)
        with open(man_path, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)
        self._gc()
        return final

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    @staticmethod
    def _decode(arr: np.ndarray, dtype_str: str) -> np.ndarray:
        """Undo numpy's void-dtype storage of ml_dtypes arrays (bf16/fp8)."""
        if arr.dtype.kind == "V":
            return arr.view(np.dtype(getattr(ml_dtypes, dtype_str)))
        return arr

    def _validate(self, step: int) -> bool:
        d = self._step_dir(step)
        try:
            with open(os.path.join(d, _MANIFEST)) as f:
                manifest = json.load(f)
            with np.load(os.path.join(d, "arrays.npz")) as z:
                for path, info in manifest["arrays"].items():
                    arr = z[path.replace("/", "\x1f")]
                    if list(arr.shape) != info["shape"]:
                        return False
                    if _digest(arr) != info["digest"]:
                        return False
            return True
        except Exception:
            return False

    def latest_valid_step(self) -> Optional[int]:
        for s in reversed(self.all_steps()):
            if self._validate(s):
                return s
        return None

    def restore(self, step: Optional[int] = None,
                shardings: Optional[dict] = None) -> tuple:
        """Returns (step, tree, extra). ``shardings``: flat path->NamedSharding
        for elastic re-sharding onto the current mesh; None -> host arrays.
        """
        if step is None:
            step = self.latest_valid_step()
            if step is None:
                raise FileNotFoundError(f"no valid checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, _MANIFEST)) as f:
            manifest = json.load(f)
        flat = {}
        with np.load(os.path.join(d, "arrays.npz")) as z:
            for path, info in manifest["arrays"].items():
                arr = self._decode(z[path.replace("/", "\x1f")], info["dtype"])
                if shardings is not None and path in shardings:
                    flat[path] = jax.device_put(arr, shardings[path])
                else:
                    flat[path] = arr
        return step, tree_from_flat(flat), manifest.get("extra", {})
