"""DeepSeek-V3-671B [arXiv:2412.19437] — MLA + MoE (1 shared + 256 routed
top-8) + multi-token prediction.

61L d_model=7168 128H (MLA: q_lora=1536, kv_lora=512, nope=128, rope=64,
v=128) expert d_ff=2048 vocab=129280. First 3 layers dense (d_ff=18432) per
the paper; MTP depth 1.
"""
from repro.models.lm import LMConfig
from repro.nn.moe import MoEConfig


def config(**ov) -> LMConfig:
    n_layers = 61
    base = dict(
        name="deepseek_v3_671b",
        n_layers=n_layers,
        d_model=7168,
        vocab_size=129280,
        n_heads=128,
        n_kv_heads=128,
        d_head=128,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        d_ff=18432,                      # dense layers (first 3)
        activation="swiglu",
        norm="rmsnorm",
        block_types=("mla",) * n_layers,
        moe_layers=tuple(range(3, n_layers)),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert_ff=2048,
                      n_shared_experts=1, d_shared_ff=2048),
        mtp_depth=1,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="deepseek_smoke",
        n_layers=3,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=4,
        d_head=32,
        q_lora_rank=64,
        kv_lora_rank=32,
        qk_nope_dim=32,
        qk_rope_dim=16,
        v_head_dim=32,
        d_ff=256,
        block_types=("mla",) * 3,
        moe_layers=(1, 2),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=64,
                      n_shared_experts=1, d_shared_ff=64, token_chunk=64,
                      capacity_factor=4.0),
        mtp_depth=1,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
