"""Hymba-1.5B [arXiv:2411.13676] — hybrid parallel attention+mamba heads.

32L d_model=1600 25H (GQA kv=5, d_head=64) d_ff=5504 vocab=32001,
ssm_state=16. Every layer runs attention and mamba heads in parallel on the
shared input norm; most layers use sliding-window attention with three
global-attention layers (first / middle / last), per the paper.
Simplifications noted in DESIGN.md: meta-tokens and cross-layer KV sharing
are not modeled.
"""
from repro.models.lm import LMConfig
from repro.nn.mamba import SSMConfig


def config(**ov) -> LMConfig:
    d_model = 1600
    base = dict(
        name="hymba_1p5b",
        n_layers=32,
        d_model=d_model,
        vocab_size=32001,
        n_heads=25,
        n_kv_heads=5,
        d_head=64,
        d_ff=5504,
        activation="swiglu",
        norm="rmsnorm",
        sliding_window=1024,
        global_attn_layers=(0, 15, 31),
        block_types=("hybrid",) * 32,
        ssm=SSMConfig(d_model=d_model, d_inner=2 * d_model, d_state=16,
                      head_dim=64),
        tie_embeddings=True,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="hymba_smoke",
        n_layers=4,
        d_model=128,
        vocab_size=512,
        n_heads=5,
        n_kv_heads=1,
        d_head=16,
        d_ff=256,
        activation="swiglu",
        sliding_window=32,
        global_attn_layers=(0,),
        block_types=("hybrid",) * 4,
        ssm=SSMConfig(d_model=128, d_inner=256, d_state=16, head_dim=32),
        tie_embeddings=True,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
