"""Llama-3.2-1B-Instruct — the paper's small evaluation model (Sec. 3.1).

16L d_model=2048 32H (GQA kv=8, d_head=64) d_ff=8192 vocab=128256.
The PTQ benchmarks run the reduced ``bench_config`` on CPU; the full config
is exercised via the dry-run like every other arch.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="llama3_1b",
        n_layers=16,
        d_model=2048,
        vocab_size=128256,
        n_heads=32,
        n_kv_heads=8,
        d_head=64,
        d_ff=8192,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=5e5,
        tie_embeddings=True,
    )
    base.update(ov)
    return LMConfig(**base)


def bench_config(**ov) -> LMConfig:
    """CPU-runnable stand-in keeping the llama block structure (~4M params)."""
    base = dict(
        name="llama3_bench",
        n_layers=6,
        d_model=192,
        vocab_size=2048,
        n_heads=6,
        n_kv_heads=2,
        d_head=32,
        d_ff=768,
        activation="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
        flash_min_seq=1 << 30,
        loss_chunk=128,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="llama3_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=256,
        tie_embeddings=True,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
