"""Llama-3.1-8B-Instruct — the paper's large evaluation model (Sec. 3.1).

32L d_model=4096 32H (GQA kv=8, d_head=128) d_ff=14336 vocab=128256.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="llama3_8b",
        n_layers=32,
        d_model=4096,
        vocab_size=128256,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=14336,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=5e5,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="llama8b_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=256,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
