"""LLaVA-NeXT-34B backbone [hf:llava-hf/llava-v1.6-*] — VLM; anyres patch
frontend is a STUB (``input_specs`` provides precomputed patch embeddings as
``prefix_embeds``).

60L d_model=7168 56H (GQA kv=8, d_head=128) d_ff=20480 vocab=64000.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="llava_next_34b",
        n_layers=60,
        d_model=7168,
        vocab_size=64000,
        n_heads=56,
        n_kv_heads=8,
        d_head=128,
        d_ff=20480,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=5e6,
        prefix_embed=True,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="llava_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=256,
        prefix_embed=True,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
