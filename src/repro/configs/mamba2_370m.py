"""Mamba2-370m [arXiv:2405.21060] — attention-free SSD (state-space duality).

48L d_model=1024 (d_inner=2048, head_dim=64 -> 32 ssm heads, d_state=128),
no MLP (d_ff=0), vocab=50280.
"""
from repro.models.lm import LMConfig
from repro.nn.mamba import SSMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="mamba2_370m",
        n_layers=48,
        d_model=1024,
        vocab_size=50280,
        d_ff=0,
        block_types=("mamba",) * 48,
        ssm=SSMConfig(d_model=1024, d_inner=2048, d_state=128, head_dim=64),
        norm="rmsnorm",
        tie_embeddings=True,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="mamba2_smoke",
        n_layers=4,
        d_model=128,
        vocab_size=512,
        d_ff=0,
        block_types=("mamba",) * 4,
        ssm=SSMConfig(d_model=128, d_inner=256, d_state=32, head_dim=32,
                      chunk=32),
        tie_embeddings=True,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
