"""Moonshot-v1-16B-A3B (Kimi / Moonlight family)
[hf:moonshotai/Moonlight-16B-A3B] — MoE, 64 experts top-6.

48L d_model=2048 16H (kv=16, d_head=128) expert d_ff=1408 vocab=163840.
All layers MoE per the assignment line (the released Moonlight also has a
dense first layer + shared experts; the assignment spec takes precedence —
noted in DESIGN.md).
"""
from repro.models.lm import LMConfig
from repro.nn.moe import MoEConfig


def config(**ov) -> LMConfig:
    n_layers = 48
    base = dict(
        name="moonshot_v1_16b_a3b",
        n_layers=n_layers,
        d_model=2048,
        vocab_size=163840,
        n_heads=16,
        n_kv_heads=16,
        d_head=128,
        d_ff=0,
        activation="swiglu",
        norm="rmsnorm",
        moe_layers=tuple(range(n_layers)),
        moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408),
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="moonshot_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=4,
        d_head=32,
        d_ff=0,
        moe_layers=(0, 1),
        moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=64, token_chunk=64,
                      capacity_factor=4.0),
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
