"""Nemotron-4-15B [arXiv:2402.16819] — dense GQA with squared-ReLU MLP.

32L d_model=6144 48H (GQA kv=8, d_head=128) d_ff=24576 vocab=256000.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="nemotron_4_15b",
        n_layers=32,
        d_model=6144,
        vocab_size=256000,
        n_heads=48,
        n_kv_heads=8,
        d_head=128,
        d_ff=24576,
        activation="relu2",
        norm="layernorm",
        rope_theta=10000.0,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="nemotron_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=512,
        activation="relu2",
        norm="layernorm",
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
