"""Qwen2.5-32B [hf:Qwen/Qwen2.5-*] — dense GQA with QKV bias.

64L d_model=5120 40H (GQA kv=8, d_head=128) d_ff=27648 vocab=152064.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="qwen2p5_32b",
        n_layers=64,
        d_model=5120,
        vocab_size=152064,
        n_heads=40,
        n_kv_heads=8,
        d_head=128,
        d_ff=27648,
        qkv_bias=True,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="qwen32b_smoke",
        n_layers=2,
        d_model=160,
        vocab_size=512,
        n_heads=5,
        n_kv_heads=1,
        d_head=32,
        d_ff=320,
        qkv_bias=True,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
