"""Qwen2.5-3B [hf:Qwen/Qwen2.5-*] — dense GQA with QKV bias, tied embeddings.

36L d_model=2048 16H (GQA kv=2, d_head=128) d_ff=11008 vocab=151936.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="qwen2p5_3b",
        n_layers=36,
        d_model=2048,
        vocab_size=151936,
        n_heads=16,
        n_kv_heads=2,
        d_head=128,
        d_ff=11008,
        qkv_bias=True,
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=1e6,
        tie_embeddings=True,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="qwen3b_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=2,
        d_head=32,
        d_ff=256,
        qkv_bias=True,
        tie_embeddings=True,
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
