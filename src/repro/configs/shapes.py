"""Assigned input-shape cells and their abstract input specs.

Every (arch x shape) cell lowers exactly one step function:
* ``train_4k``   -> train_step (loss + grads + optimizer update)
* ``prefill_32k``-> serve_step prefill (TTFT — the paper's measured metric)
* ``decode_32k`` -> serve_step decode (1 new token, KV cache of seq_len)
* ``long_500k``  -> serve_step decode at 524288 context — only sub-quadratic
                    archs (SSM/hybrid); full-attention archs skip (DESIGN.md).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.encdec import EncDec
from repro.models.lm import LM


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# archs with a sub-quadratic token-mixing path (may run long_500k)
SUBQUADRATIC = {"hymba_1p5b", "mamba2_370m"}

# decoder prompt length used for enc-dec prefill cells (encoder gets seq_len)
ENCDEC_DEC_PROMPT = 128
# image-token prefix length for the VLM stub
VLM_PREFIX_TOKENS = 576


def cell_supported(arch: str, shape_name: str) -> tuple:
    """(supported, reason)."""
    if shape_name == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: 524k-token decode is quadratic-KV"
    return True, ""


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(model, cell: ShapeCell) -> dict:
    """Abstract inputs for the cell's step function (no allocation)."""
    B, S = cell.global_batch, cell.seq_len
    cfg = model.cfg
    if isinstance(model, EncDec):
        if cell.kind == "train":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": _tok((B, S)), "labels": _tok((B, S))}
        if cell.kind == "prefill":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16),
                    "tokens": _tok((B, ENCDEC_DEC_PROMPT))}
        return {"token": _tok((B, 1)), "pos": _tok(())}

    assert isinstance(model, LM)
    if cfg.prefix_embed:
        P = VLM_PREFIX_TOKENS
        if cell.kind == "train":
            return {"tokens": _tok((B, S - P)), "labels": _tok((B, S - P)),
                    "prefix_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                          jnp.bfloat16)}
        if cell.kind == "prefill":
            return {"tokens": _tok((B, S - P)),
                    "prefix_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                          jnp.bfloat16)}
        return {"token": _tok((B, 1)), "pos": _tok(())}

    if cell.kind == "train":
        return {"tokens": _tok((B, S)), "labels": _tok((B, S))}
    if cell.kind == "prefill":
        return {"tokens": _tok((B, S))}
    return {"token": _tok((B, 1)), "pos": _tok(())}
