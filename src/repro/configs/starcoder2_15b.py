"""StarCoder2-15B [arXiv:2402.19173] — dense GQA, RoPE, GELU, LayerNorm.

40L d_model=6144 48H (GQA kv=4, d_head=128) d_ff=24576 vocab=49152.
"""
from repro.models.lm import LMConfig


def config(**ov) -> LMConfig:
    base = dict(
        name="starcoder2_15b",
        n_layers=40,
        d_model=6144,
        vocab_size=49152,
        n_heads=48,
        n_kv_heads=4,
        d_head=128,
        d_ff=24576,
        activation="gelu",
        norm="layernorm",
        rope_theta=1e5,
    )
    base.update(ov)
    return LMConfig(**base)


def smoke_config(**ov) -> LMConfig:
    base = dict(
        name="starcoder2_smoke",
        n_layers=2,
        d_model=128,
        vocab_size=512,
        n_heads=4,
        n_kv_heads=1,
        d_head=32,
        d_ff=512,
        activation="gelu",
        norm="layernorm",
        flash_min_seq=1 << 30,
        loss_chunk=64,
    )
    base.update(ov)
    return LMConfig(**base)
