"""Whisper-base [arXiv:2212.04356] — encoder-decoder backbone, conv frontend
stubbed (``input_specs`` provides precomputed frame embeddings).

6L enc + 6L dec, d_model=512 8H (kv=8, d_head=64) d_ff=2048 vocab=51865.
"""
from repro.models.encdec import EncDecConfig


def config(**ov) -> EncDecConfig:
    base = dict(
        name="whisper_base",
        n_enc_layers=6,
        n_dec_layers=6,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_head=64,
        d_ff=2048,
        vocab_size=51865,
        activation="gelu",
        norm="layernorm",
    )
    base.update(ov)
    return EncDecConfig(**base)


def smoke_config(**ov) -> EncDecConfig:
    base = dict(
        name="whisper_smoke",
        n_enc_layers=2,
        n_dec_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=512,
        activation="gelu",
        norm="layernorm",
        flash_min_seq=1 << 30,
    )
    base.update(ov)
    return EncDecConfig(**base)
