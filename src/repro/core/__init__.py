# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
from repro.core.mpconfig import MPPlan, as_assignment
from repro.core.pipeline import (AMPOptions, CalibrationBundle,
                                 auto_mixed_precision, calibrate,
                                 predicted_loss_mse,
                                 tabulate_measured_gains)
from repro.core.registry import BundleRegistry

__all__ = ["MPPlan", "as_assignment", "AMPOptions", "BundleRegistry",
           "CalibrationBundle", "auto_mixed_precision", "calibrate",
           "predicted_loss_mse", "tabulate_measured_gains"]
