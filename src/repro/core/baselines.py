"""Baseline MP strategies from the paper's evaluation (Sec. 3.1).

* Random — arbitrarily picks layers to quantize while the predicted loss MSE
  stays under the budget (scattered patterns, Fig. 2 bottom).
* Prefix — quantizes layers in sequential (topological) order until the
  budget is reached (Fig. 2 middle).

Both respect the same tau^2 E[g^2] constraint as the IP strategies.
"""
from __future__ import annotations

import random as _random
from typing import Optional, Sequence

from repro.core.sensitivity import SensitivityResult
from repro.quant.formats import get_format

__all__ = ["random_strategy", "prefix_strategy"]


def _d(sens: SensitivityResult, name: str, fmt: str, ref: str) -> float:
    if fmt == ref:
        return 0.0
    return sens.sensitivity.get(name, 0.0) * get_format(fmt).alpha


def random_strategy(op_names: Sequence[str], sens: SensitivityResult,
                    budget: float, fmt: str = "fp8_e4m3", ref: str = "bf16",
                    seed: int = 0) -> dict:
    rng = _random.Random(seed)
    order = list(op_names)
    rng.shuffle(order)
    assignment = {}
    used = 0.0
    for name in order:
        d = _d(sens, name, fmt, ref)
        if used + d <= budget:
            assignment[name] = fmt
            used += d
    return assignment


def prefix_strategy(op_names: Sequence[str], sens: SensitivityResult,
                    budget: float, fmt: str = "fp8_e4m3",
                    ref: str = "bf16") -> dict:
    assignment = {}
    used = 0.0
    for name in op_names:  # topological order as provided
        d = _d(sens, name, fmt, ref)
        if used + d > budget:
            break
        assignment[name] = fmt
        used += d
    return assignment
