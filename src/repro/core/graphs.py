"""Computation-DAG builders for the model zoo.

Node names of quantizable ops match the ``qops`` op names exactly (and hence
the param paths), so the partition output indexes straight into sensitivity
results and MP assignments. Non-quantizable vertices (norms, softmax,
elementwise merges, residual adds) are included because they shape the
single-entry/single-exit structure.

Residual adds are recorded as *residual edges* so the partitioner can drop
them (paper Fig. 6 note). The builders mirror the *serving* (prefill)
computation: MTP blocks and training-only ops are excluded.
"""
from __future__ import annotations

from repro.core.partition import GraphSpec
from repro.models.encdec import EncDec, EncDecConfig
from repro.models.lm import LM, LMConfig

__all__ = ["build_graph"]


def _attn_subgraph(g: GraphSpec, s: str, entry: str, swiglu_like: bool) -> str:
    """Standard attention: returns exit node name."""
    norm = g.add(f"{s}/attn_norm")
    g.edge(entry, norm)
    for proj in ("q_proj", "k_proj", "v_proj"):
        g.add(f"{s}/attn/{proj}", quantizable=True)
        g.edge(norm, f"{s}/attn/{proj}")
    qk = g.add(f"{s}/attn/qk_matmul", quantizable=True)
    g.edge(f"{s}/attn/q_proj", qk)
    g.edge(f"{s}/attn/k_proj", qk)
    sm = g.add(f"{s}/attn/softmax")
    g.edge(qk, sm)
    av = g.add(f"{s}/attn/av_matmul", quantizable=True)
    g.edge(sm, av)
    g.edge(f"{s}/attn/v_proj", av)
    o = g.add(f"{s}/attn/o_proj", quantizable=True)
    g.edge(av, o)
    return o


def _mla_subgraph(g: GraphSpec, s: str, entry: str) -> str:
    norm = g.add(f"{s}/attn_norm")
    g.edge(entry, norm)
    g.chain(norm, g.add(f"{s}/attn/q_a_proj", True), g.add(f"{s}/attn/q_norm"),
            g.add(f"{s}/attn/q_b_proj", True))
    g.chain(norm, g.add(f"{s}/attn/kv_a_proj", True), g.add(f"{s}/attn/kv_norm"),
            g.add(f"{s}/attn/kv_b_proj", True))
    qk = g.add(f"{s}/attn/qk_matmul", True)
    g.edge(f"{s}/attn/q_b_proj", qk)
    g.edge(f"{s}/attn/kv_b_proj", qk)
    sm = g.add(f"{s}/attn/softmax")
    g.edge(qk, sm)
    av = g.add(f"{s}/attn/av_matmul", True)
    g.edge(sm, av)
    g.edge(f"{s}/attn/kv_b_proj", av)
    o = g.add(f"{s}/attn/o_proj", True)
    g.edge(av, o)
    return o


def _mamba_subgraph(g: GraphSpec, s: str, entry: str) -> str:
    norm = g.add(f"{s}/attn_norm")  # shared input norm naming from LM._block
    g.edge(entry, norm)
    return _mamba_shared_norm(g, s, norm)


def _mamba_shared_norm(g: GraphSpec, s: str, norm: str) -> str:
    """Mamba path when the input norm already exists (hybrid blocks)."""
    inp = g.add(f"{s}/mamba/in_proj", True)
    g.edge(norm, inp)
    conv = g.add(f"{s}/mamba/conv")
    g.edge(inp, conv)
    cb = g.add(f"{s}/mamba/cb_matmul", True)
    g.edge(conv, cb)
    ax = g.add(f"{s}/mamba/att_x_matmul", True)
    g.edge(cb, ax)
    g.edge(conv, ax)
    bx = g.add(f"{s}/mamba/bx_matmul", True)
    g.edge(conv, bx)
    cs = g.add(f"{s}/mamba/c_state_matmul", True)
    g.edge(bx, cs)
    g.edge(conv, cs)
    merge = g.add(f"{s}/mamba/merge")
    g.edge(ax, merge)
    g.edge(cs, merge)
    gate = g.add(f"{s}/mamba/gate_norm")
    g.edge(merge, gate)
    out = g.add(f"{s}/mamba/out_proj", True)
    g.edge(gate, out)
    return out


def _mlp_subgraph(g: GraphSpec, s: str, entry: str, activation: str,
                  scope: str = "mlp") -> str:
    norm = g.add(f"{s}/mlp_norm")
    g.edge(entry, norm)
    if activation == "swiglu":
        gate = g.add(f"{s}/{scope}/gate_proj", True)
        up = g.add(f"{s}/{scope}/up_proj", True)
        g.edge(norm, gate)
        g.edge(norm, up)
        mul = g.add(f"{s}/{scope}/glu_mul")
        g.edge(gate, mul)
        g.edge(up, mul)
        pre_down = mul
    else:
        up = g.add(f"{s}/{scope}/up_proj", True)
        g.edge(norm, up)
        act = g.add(f"{s}/{scope}/act")
        g.edge(up, act)
        pre_down = act
    down = g.add(f"{s}/{scope}/down_proj", True)
    g.edge(pre_down, down)
    return down


def _moe_subgraph(g: GraphSpec, s: str, entry: str, activation: str,
                  shared: bool) -> str:
    norm = g.add(f"{s}/mlp_norm")
    g.edge(entry, norm)
    router = g.add(f"{s}/moe/router", True)
    g.edge(norm, router)
    disp = g.add(f"{s}/moe/dispatch")
    g.edge(router, disp)
    gate = g.add(f"{s}/moe/experts/gate_proj", True)
    up = g.add(f"{s}/moe/experts/up_proj", True)
    g.edge(disp, gate)
    g.edge(disp, up)
    mul = g.add(f"{s}/moe/glu_mul")
    g.edge(gate, mul)
    g.edge(up, mul)
    down = g.add(f"{s}/moe/experts/down_proj", True)
    g.edge(mul, down)
    comb = g.add(f"{s}/moe/combine")
    g.edge(down, comb)
    exit_node = comb
    if shared:
        sh = _mlp_subgraph(g, f"{s}/moe", norm, activation, scope="shared")
        # shared path merges with routed output
        merge = g.add(f"{s}/moe/shared_merge")
        g.edge(comb, merge)
        g.edge(sh, merge)
        exit_node = merge
    return exit_node


def build_lm_graph(cfg: LMConfig) -> GraphSpec:
    g = GraphSpec()
    prev = g.add("embed")
    scopes = ([(f"segments/{s}", sig) for s, (sig, _) in enumerate(cfg.segments())]
              if cfg.scan_layers else
              [(f"layers/{i}", cfg.layer_signature(i)) for i in range(cfg.n_layers)])
    for s, (block, is_moe) in scopes:
        block_in = prev
        if block == "attn":
            mix_out = _attn_subgraph(g, s, prev, cfg.activation == "swiglu")
        elif block == "mla":
            mix_out = _mla_subgraph(g, s, prev)
        elif block == "mamba":
            mix_out = _mamba_subgraph(g, s, prev)
        elif block == "hybrid":
            norm = g.add(f"{s}/attn_norm")
            g.edge(prev, norm)
            # attention path (reuse the attn nodes but from the shared norm)
            for proj in ("q_proj", "k_proj", "v_proj"):
                g.add(f"{s}/attn/{proj}", True)
                g.edge(norm, f"{s}/attn/{proj}")
            qk = g.add(f"{s}/attn/qk_matmul", True)
            g.edge(f"{s}/attn/q_proj", qk)
            g.edge(f"{s}/attn/k_proj", qk)
            sm = g.add(f"{s}/attn/softmax")
            g.edge(qk, sm)
            av = g.add(f"{s}/attn/av_matmul", True)
            g.edge(sm, av)
            g.edge(f"{s}/attn/v_proj", av)
            o = g.add(f"{s}/attn/o_proj", True)
            g.edge(av, o)
            m_out = _mamba_shared_norm(g, s, norm)
            mix_out = g.add(f"{s}/hybrid_merge")
            g.edge(o, mix_out)
            g.edge(m_out, mix_out)
        else:
            raise ValueError(block)
        add1 = g.add(f"{s}/residual_1")
        g.edge(mix_out, add1)
        g.edge(block_in, add1, residual=True)
        if is_moe:
            ffn_out = _moe_subgraph(g, s, add1, cfg.activation,
                                    cfg.moe.n_shared_experts > 0)
        elif cfg.d_ff > 0:
            ffn_out = _mlp_subgraph(g, s, add1, cfg.activation)
        else:
            prev = add1
            continue
        add2 = g.add(f"{s}/residual_2")
        g.edge(ffn_out, add2)
        g.edge(add1, add2, residual=True)
        prev = add2
    fn = g.add("final_norm")
    g.edge(prev, fn)
    head = g.add("lm_head", True)
    g.edge(fn, head)
    return g


def build_encdec_graph(cfg: EncDecConfig) -> GraphSpec:
    g = GraphSpec()
    prev = g.add("frames")
    for i in range(cfg.n_enc_layers):
        s = f"enc/{i}"
        block_in = prev
        o = _attn_subgraph(g, s, prev, False)
        add1 = g.add(f"{s}/residual_1")
        g.edge(o, add1)
        g.edge(block_in, add1, residual=True)
        m = _mlp_subgraph(g, s, add1, cfg.activation)
        add2 = g.add(f"{s}/residual_2")
        g.edge(m, add2)
        g.edge(add1, add2, residual=True)
        prev = add2
    enc_out = g.add("enc_final_norm")
    g.edge(prev, enc_out)
    prev = g.add("dec_embed")
    g.edge(enc_out, prev)  # decoder consumes encoder output (sequentializes)
    for i in range(cfg.n_dec_layers):
        s = f"dec/{i}"
        block_in = prev
        o = _attn_subgraph(g, s, prev, False)
        add1 = g.add(f"{s}/residual_1")
        g.edge(o, add1)
        g.edge(block_in, add1, residual=True)
        # cross-attention (k/v from encoder; q from decoder stream)
        cn = g.add(f"{s}/cross_norm")
        g.edge(add1, cn)
        for proj in ("q_proj", "k_proj", "v_proj"):
            g.add(f"{s}/cross/{proj}", True)
            g.edge(cn, f"{s}/cross/{proj}")
        qk = g.add(f"{s}/cross/qk_matmul", True)
        g.edge(f"{s}/cross/q_proj", qk)
        g.edge(f"{s}/cross/k_proj", qk)
        smx = g.add(f"{s}/cross/softmax")
        g.edge(qk, smx)
        av = g.add(f"{s}/cross/av_matmul", True)
        g.edge(smx, av)
        g.edge(f"{s}/cross/v_proj", av)
        o2 = g.add(f"{s}/cross/o_proj", True)
        g.edge(av, o2)
        add_c = g.add(f"{s}/residual_cross")
        g.edge(o2, add_c)
        g.edge(add1, add_c, residual=True)
        m = _mlp_subgraph(g, s, add_c, cfg.activation)
        add2 = g.add(f"{s}/residual_2")
        g.edge(m, add2)
        g.edge(add_c, add2, residual=True)
        prev = add2
    fn = g.add("dec_final_norm")
    g.edge(prev, fn)
    head = g.add("lm_head", True)
    g.edge(fn, head)
    return g


def build_graph(model) -> GraphSpec:
    if isinstance(model, EncDec):
        return build_encdec_graph(model.cfg)
    if isinstance(model, LM):
        return build_lm_graph(model.cfg)
    raise TypeError(type(model))
