"""Integer program (eq. 5): Multiple-Choice Knapsack.

    max  sum_j c[j][p_j]      s.t.  sum_j d[j][p_j] <= budget,
    one configuration p_j per group j.

Solvers:
* ``brute``     — exact enumeration (small instances / tests).
* ``dp``        — pseudo-polynomial dynamic program over a discretized budget
                  grid. Costs are rounded *up*, so any returned selection is
                  feasible for the true budget (conservative).
* ``lp_greedy`` — dominance- and convex-hull-pruned greedy on incremental
                  efficiency; yields both a feasible solution and the LP
                  upper bound used to certify the dp gap.
* ``auto``      — brute when the product of choices is small, else dp and
                  lp_greedy, returning the better feasible solution plus the
                  LP bound / optimality gap.

Beyond-paper (lossless): per-group Pareto pruning of dominated configs.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Optional, Sequence

import numpy as np

__all__ = ["MCKPGroup", "MCKPResult", "solve_mckp", "pareto_prune"]


@dataclasses.dataclass
class MCKPGroup:
    name: str
    labels: list            # payload per config (e.g. tuple of formats)
    c: np.ndarray           # gain per config (maximize)
    d: np.ndarray           # loss-MSE per config (constrained)

    def __post_init__(self):
        self.c = np.asarray(self.c, np.float64)
        self.d = np.asarray(self.d, np.float64)
        assert len(self.labels) == len(self.c) == len(self.d)
        assert np.all(self.d >= -1e-18), "loss MSE must be non-negative"


@dataclasses.dataclass
class MCKPResult:
    selection: list         # chosen config index per group (original indexing)
    labels: list            # chosen payloads
    c_total: float
    d_total: float
    upper_bound: float      # LP bound on the optimum
    method: str

    @property
    def gap(self) -> float:
        if self.upper_bound <= 0:
            return 0.0
        return max(0.0, (self.upper_bound - self.c_total) / abs(self.upper_bound))


def pareto_prune(group: MCKPGroup) -> tuple:
    """Remove configs dominated by another (d' <= d and c' >= c).

    Returns (kept original indices sorted by d, pruned group arrays).
    """
    order = np.lexsort((-group.c, group.d))
    kept = []
    best_c = -math.inf
    for i in order:
        if group.c[i] > best_c + 1e-18:
            kept.append(int(i))
            best_c = group.c[i]
    return kept, group.c[kept], group.d[kept]


def _solve_brute(groups: Sequence[MCKPGroup], budget: float):
    best = None
    for combo in itertools.product(*[range(len(g.c)) for g in groups]):
        d = sum(g.d[i] for g, i in zip(groups, combo))
        if d > budget + 1e-15:
            continue
        c = sum(g.c[i] for g, i in zip(groups, combo))
        if best is None or c > best[0]:
            best = (c, d, list(combo))
    if best is None:
        raise ValueError("infeasible: no combination satisfies the budget")
    return best


def _lp_greedy(pruned, budget: float):
    """Greedy on the per-group convex hull of (d, c); LP bound + feasible pick.

    pruned: list of (kept_idx, c, d) per group with d ascending, c ascending.
    """
    # start from each group's min-d config; must be feasible
    sel = [0] * len(pruned)
    base_d = sum(p[2][0] for p in pruned)
    base_c = sum(p[1][0] for p in pruned)
    if base_d > budget + 1e-15:
        raise ValueError("infeasible: even minimal-d selection exceeds budget")

    # convex-hull increments per group
    steps = []  # (ratio, group, from_idx, to_idx, dc, dd)
    for gi, (_, c, d) in enumerate(pruned):
        hull = [0]
        for j in range(1, len(c)):
            while len(hull) >= 2:
                a, b = hull[-2], hull[-1]
                r_ab = (c[b] - c[a]) / max(d[b] - d[a], 1e-300)
                r_bj = (c[j] - c[b]) / max(d[j] - d[b], 1e-300)
                if r_bj >= r_ab:
                    hull.pop()
                else:
                    break
            if c[j] > c[hull[-1]]:
                hull.append(j)
        for a, b in zip(hull, hull[1:]):
            dd = d[b] - d[a]
            dc = c[b] - c[a]
            steps.append((dc / max(dd, 1e-300), gi, a, b, dc, dd))
    steps.sort(key=lambda t: -t[0])

    rem = budget - base_d
    c_tot = base_c
    ub = base_c
    cur = {gi: 0 for gi in range(len(pruned))}
    for ratio, gi, a, b, dc, dd in steps:
        if cur[gi] != a:
            continue  # superseded (hull steps are sequential per group)
        if dd <= rem + 1e-15:
            rem -= dd
            c_tot += dc
            ub += dc
            cur[gi] = b
            sel[gi] = b
        else:
            ub += dc * (rem / max(dd, 1e-300))  # fractional LP completion
            break
    return sel, c_tot, budget - rem, ub


def _solve_dp(pruned, budget: float, bins: int):
    """DP over discretized budget. Costs rounded up -> always feasible."""
    J = len(pruned)
    if budget <= 0.0 or not np.isfinite(bins / budget):
        # zero or subnormal budget: only zero-cost configs are admissible
        sel, c_tot = [], 0.0
        for _, c, d in pruned:
            feas = [p for p in range(len(c)) if d[p] <= 0.0]
            if not feas:
                raise ValueError("infeasible at zero budget")
            p = max(feas, key=lambda i: c[i])
            sel.append(p)
            c_tot += c[p]
        return sel, c_tot
    scale = bins / budget
    NEG = -1e30
    dp = np.full(bins + 1, NEG)
    dp[0] = 0.0
    choice = np.zeros((J, bins + 1), np.int32)
    for gi, (_, c, d) in enumerate(pruned):
        # clip in float space BEFORE the int cast: ceil(d*scale) can exceed
        # int64 range at tiny budgets (overflow -> negative index)
        db = np.minimum(np.ceil(d * scale), bins + 1).astype(np.int64)
        new = np.full(bins + 1, NEG)
        pick = np.zeros(bins + 1, np.int32)
        for p in range(len(c)):
            if db[p] > bins:
                continue
            shifted = np.full(bins + 1, NEG)
            if db[p] == 0:
                shifted = dp + c[p]
            else:
                shifted[db[p]:] = dp[:bins + 1 - db[p]] + c[p]
            better = shifted > new
            new = np.where(better, shifted, new)
            pick = np.where(better, p, pick)
        dp = new
        choice[gi] = pick
    b_star = int(np.argmax(dp))
    if dp[b_star] <= NEG / 2:
        raise ValueError("infeasible under dp discretization")
    sel = [0] * J
    b = b_star
    for gi in range(J - 1, -1, -1):
        p = int(choice[gi, b])
        sel[gi] = p
        db = int(min(np.ceil(pruned[gi][2][p] * scale), bins))
        b -= db
    return sel, float(dp[b_star])


def solve_mckp(groups: Sequence[MCKPGroup], budget: float,
               method: str = "auto", bins: int = 8192,
               brute_limit: int = 200_000) -> MCKPResult:
    assert budget >= 0
    pruned = [pareto_prune(g) for g in groups]

    n_combos = 1
    for g in groups:
        n_combos *= len(g.c)
        if n_combos > brute_limit:
            break

    if method == "brute" or (method == "auto" and n_combos <= brute_limit):
        c, d, sel = _solve_brute(groups, budget)
        _, _, _, ub = _lp_greedy(pruned, budget)
        return MCKPResult(sel, [g.labels[i] for g, i in zip(groups, sel)],
                          float(c), float(d), float(max(ub, c)), "brute")

    sel_g, c_g, d_g, ub = _lp_greedy(pruned, budget)
    best = ("lp_greedy", sel_g, c_g)
    if method in ("auto", "dp"):
        sel_dp, c_dp = _solve_dp(pruned, budget, bins)
        if c_dp > c_g:
            best = ("dp", sel_dp, c_dp)
    method_used, sel_p, _ = best
    # map pruned indices back to original config indices
    sel = [pruned[gi][0][p] for gi, p in enumerate(sel_p)]
    c_tot = float(sum(g.c[i] for g, i in zip(groups, sel)))
    d_tot = float(sum(g.d[i] for g, i in zip(groups, sel)))
    assert d_tot <= budget * (1 + 1e-9) + 1e-12
    return MCKPResult(sel, [g.labels[i] for g, i in zip(groups, sel)],
                      c_tot, d_tot, float(max(ub, c_tot)), method_used)
