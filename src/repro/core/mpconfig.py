"""Mixed-precision plan: the pipeline's output artifact."""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

__all__ = ["MPPlan"]


@dataclasses.dataclass
class MPPlan:
    assignment: dict                 # op name -> format name (bf16 omitted ok)
    groups: list                     # list[list[op name]]
    objective: str                   # ET | TT | M
    tau: float
    budget: float                    # tau^2 * E[g^2]
    predicted_loss_mse: float
    predicted_gain: float
    ip_gap: float = 0.0
    meta: dict = dataclasses.field(default_factory=dict)

    def format_for(self, op_name: str) -> str:
        return self.assignment.get(op_name, "bf16")

    @property
    def n_quantized(self) -> int:
        return sum(1 for f in self.assignment.values() if f != "bf16")

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "MPPlan":
        return cls(**json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "MPPlan":
        with open(path) as f:
            return cls.from_json(f.read())
