"""Mixed-precision plan: the pipeline's output artifact.

A plan flows into serving through :func:`as_assignment`: every engine / step
builder accepts ``mp`` as either a raw ``op name -> format`` dict or an
``MPPlan`` and normalizes it here, so the IP solver's artifact is directly
servable (``auto_mixed_precision(...) -> ServeEngine(model, mp=plan)``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Union

__all__ = ["MPPlan", "as_assignment"]


@dataclasses.dataclass
class MPPlan:
    assignment: dict                 # op name -> format name (bf16 omitted ok)
    groups: list                     # list[list[op name]]
    objective: str                   # ET | TT | M
    tau: float
    budget: float                    # tau^2 * E[g^2]
    predicted_loss_mse: float
    predicted_gain: float
    ip_gap: float = 0.0
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        # JSON turns tuple groups into lists; normalize eagerly so a plan
        # compares equal across a save/load round-trip.
        self.groups = [list(g) for g in self.groups]

    def format_for(self, op_name: str) -> str:
        return self.assignment.get(op_name, "bf16")

    def unknown_ops(self, known_ops) -> set:
        """Assignment keys that do not name an op in ``known_ops``.

        Callers that pair a plan with a model (e.g. the serving launcher)
        check this before compiling step functions: a non-empty result means
        the plan was solved for a different model (or op namespace) and its
        quantization directives would silently not apply.
        """
        known = set(known_ops)
        return {n for n in self.assignment if n not in known}

    @property
    def n_quantized(self) -> int:
        return sum(1 for f in self.assignment.values() if f != "bf16")

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "MPPlan":
        return cls(**json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "MPPlan":
        with open(path) as f:
            return cls.from_json(f.read())


def as_assignment(mp: Union[None, dict, "MPPlan"]) -> Optional[dict]:
    """Normalize an engine ``mp`` argument to an assignment dict (or None).

    Accepts ``None`` (pure bf16), a raw ``op name -> format name`` dict, or
    an :class:`MPPlan`; reference-format entries are dropped so an empty
    result collapses to ``None`` and engines skip the MP quant context.
    """
    if mp is None:
        return None
    if isinstance(mp, MPPlan):
        mp = mp.assignment
    if not isinstance(mp, dict):
        raise TypeError(f"mp must be None, dict or MPPlan, got {type(mp)}")
    mp = {n: f for n, f in mp.items() if f != "bf16"}
    return mp or None
