"""Model partition into sequential sub-graphs (paper Appendix B, Alg. 2).

The computation DAG is split into maximal single-entry/single-exit regions
("groups") that execute strictly sequentially at run time, so per-group time
gains add up (Sec. 2.3.1). The algorithm is the paper's verbatim: BFS
longest-path labels, then a frontier sweep that absorbs parallel branches
until each reconvergence point.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

__all__ = ["GraphSpec", "partition_sequential"]

START = "__start__"
END = "__end__"


@dataclasses.dataclass
class GraphSpec:
    """A DAG of named ops. Quantizable nodes correspond to qops op names."""

    nodes: dict = dataclasses.field(default_factory=dict)   # name -> quantizable
    edges: set = dataclasses.field(default_factory=set)     # (src, dst)
    residual_edges: set = dataclasses.field(default_factory=set)

    def add(self, name: str, quantizable: bool = False) -> str:
        self.nodes.setdefault(name, quantizable)
        if quantizable:
            self.nodes[name] = True
        return name

    def edge(self, src: str, dst: str, residual: bool = False) -> None:
        assert src in self.nodes and dst in self.nodes, (src, dst)
        self.edges.add((src, dst))
        if residual:
            self.residual_edges.add((src, dst))

    def chain(self, *names: str, quantizable: bool = False) -> None:
        for n in names:
            self.add(n, quantizable)
        for a, b in zip(names, names[1:]):
            self.edge(a, b)

    def successors(self, drop_residual: bool) -> dict:
        nxt: dict = {n: [] for n in self.nodes}
        for (a, b) in sorted(self.edges):
            if drop_residual and (a, b) in self.residual_edges:
                continue
            nxt[a].append(b)
        return nxt

    def quantizable_nodes(self) -> list:
        return [n for n, q in self.nodes.items() if q]


def _longest_paths(nodes: Iterable[str], nxt: dict) -> dict:
    """Longest path length from START via DP in topological order."""
    indeg = {n: 0 for n in nodes}
    for n, succs in nxt.items():
        for s in succs:
            indeg[s] += 1
    from collections import deque
    order = deque(sorted(n for n, d in indeg.items() if d == 0))
    dist = {n: 0 for n in nodes}
    topo = []
    while order:
        n = order.popleft()
        topo.append(n)
        for s in nxt[n]:
            dist[s] = max(dist[s], dist[n] + 1)
            indeg[s] -= 1
            if indeg[s] == 0:
                order.append(s)
    assert len(topo) == len(dist), "graph has a cycle"
    return dist


def partition_sequential(graph: GraphSpec, drop_residual: bool = True,
                         max_group_size: Optional[int] = None) -> list:
    """Alg. 2: returns ordered groups [[op names...], ...] of quantizable ops.

    ``drop_residual=True`` removes residual bypass edges before partitioning,
    as the paper does (Fig. 6 omits residual adds); otherwise every
    transformer block would collapse into a single group.
    ``max_group_size``: optionally split oversized groups (keeps F^L_j
    enumerable); a deviation from the paper, off by default.
    """
    g = GraphSpec(dict(graph.nodes), set(graph.edges), set(graph.residual_edges))
    nxt = g.successors(drop_residual)

    # attach virtual start/end
    has_pred = {b for (a, b) in g.edges
                if not (drop_residual and (a, b) in g.residual_edges)}
    sources = [n for n in g.nodes if n not in has_pred]
    sinks = [n for n in g.nodes if not nxt[n]]
    nodes = dict(g.nodes)
    nodes[START] = False
    nodes[END] = False
    nxt[START] = sorted(sources)
    for s in sinks:
        nxt[s] = [END]
    nxt[END] = []

    path_len = _longest_paths(nodes, nxt)

    V: list = []
    vertex = START
    visited_guard = 0
    while vertex != END:
        visited_guard += 1
        assert visited_guard <= len(nodes) + 2, "partition did not converge"
        Vp: list = []
        cur_len = path_len[vertex] + 1
        A = list(dict.fromkeys(nxt[vertex]))
        while len(A) > 1:
            progressed = False
            for v in list(A):
                if path_len[v] <= cur_len:
                    A.remove(v)
                    if v != END and v not in Vp:
                        Vp.append(v)
                    for s in nxt[v]:
                        if s not in A:
                            A.append(s)
                    progressed = True
            cur_len += 1
            if not progressed and len(A) > 1:
                # all remaining vertices deeper than cur_len: fast-forward
                cur_len = min(path_len[v] for v in A)
        vertex = A[0]
        if vertex != END and vertex not in Vp:
            Vp.append(vertex)
        # keep only quantizable ops, preserve topological order
        Vp = sorted((v for v in Vp if nodes.get(v, False)),
                    key=lambda v: (path_len[v], v))
        if Vp:
            V.append(Vp)

    if max_group_size is not None:
        out = []
        for grp in V:
            for i in range(0, len(grp), max_group_size):
                out.append(grp[i:i + max_group_size])
        V = out
    return V
