"""End-to-end automatic MP pipeline (paper Algorithm 1).

1. partition the model graph into sequential sub-graphs (Alg. 2),
2. sensitivity calibration: fwd+bwd over the calibration set (Sec. 2.2),
3. per-group gain evaluation for all F^{L_j} combos (Sec. 2.3),
4. IP (eq. 5) with the loss-MSE budget tau^2 E[g^2].
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from repro.core import graphs as G
from repro.core.ip_solver import MCKPGroup, solve_mckp
from repro.core.mpconfig import MPPlan
from repro.core.partition import partition_sequential
from repro.core.sensitivity import SensitivityResult, calibrate_sensitivity, collect_ops
from repro.core.timegain import (MemoryGainModel, RooflineGainModel,
                                 TheoreticalGainModel, enumerate_combos)
from repro.hw.profiles import TPU_V5E, HWProfile
from repro.quant.formats import get_format

__all__ = ["AMPOptions", "auto_mixed_precision", "predicted_loss_mse",
           "build_groups"]


@dataclasses.dataclass
class AMPOptions:
    tau: float = 0.005                    # normalized-RMSE threshold
    formats: tuple = ("bf16", "fp8_e4m3")
    ref_format: str = "bf16"
    objective: str = "ET"                 # ET | TT | M
    max_group_size: int = 8               # cap F^{L_j} enumeration
    drop_residual: bool = True            # paper-faithful
    ip_method: str = "auto"
    ip_bins: int = 8192
    pareto_prune: bool = True             # lossless beyond-paper speedup
    hw: HWProfile = TPU_V5E


def predicted_loss_mse(sens: SensitivityResult, assignment: dict,
                       ref: str = "bf16") -> float:
    """Eq. (6)/(23): additive per-layer loss MSE, d=0 at the reference fmt."""
    total = 0.0
    for name, fmt in assignment.items():
        if fmt == ref:
            continue
        total += sens.sensitivity.get(name, 0.0) * get_format(fmt).alpha
    return total


def build_groups(model, opts: AMPOptions, quantizable: Optional[set] = None):
    """Partition and return (graph, ordered groups of quantizable op names)."""
    graph = G.build_graph(model)
    groups = partition_sequential(graph, drop_residual=opts.drop_residual,
                                  max_group_size=opts.max_group_size)
    if quantizable is not None:
        groups = [[n for n in g if n in quantizable] for g in groups]
        groups = [g for g in groups if g]
    return graph, groups


def auto_mixed_precision(model, params, calib_batches: Iterable,
                         opts: AMPOptions, gain_model=None,
                         sens: Optional[SensitivityResult] = None,
                         loss_fn: Optional[Callable] = None) -> MPPlan:
    loss_fn = loss_fn or (lambda p, b, ctx: model.loss(p, b, ctx))

    # ---- Alg.1 line 2: sensitivity calibration ----
    if sens is None:
        sens = calibrate_sensitivity(loss_fn, params, calib_batches)
    op_index = {op.name: op for op in sens.ops}

    # ---- objective-specific op set (IP-M quantizes linear layers only) ----
    if opts.objective == "M":
        quantizable = {n for n, op in op_index.items() if op.kind == "linear"}
    else:
        quantizable = set(op_index)

    # ---- Alg.1 line 1: partition ----
    graph, groups = build_groups(model, opts, quantizable)
    if opts.objective == "M":
        # memory is additive per layer: trivial per-layer groups (Sec. 2.3.3)
        groups = [[n] for g in groups for n in g]

    # ---- Alg.1 line 3: per-group gains for all combos ----
    if gain_model is None:
        gain_model = {"ET": RooflineGainModel(opts.hw),
                      "TT": TheoreticalGainModel(opts.hw),
                      "M": MemoryGainModel()}[opts.objective]

    mckp_groups = []
    for gi, group in enumerate(groups):
        ops = [op_index[n] for n in group]
        combos = enumerate_combos(len(ops), opts.formats)
        c = gain_model.gains(ops, combos)
        d = np.array([
            sum(0.0 if f == opts.ref_format else
                sens.sensitivity.get(op.name, 0.0) * get_format(f).alpha
                for op, f in zip(ops, combo))
            for combo in combos])
        mckp_groups.append(MCKPGroup(name=f"group_{gi}", labels=combos,
                                     c=c, d=d))

    # ---- Alg.1 line 4: IP ----
    budget = opts.tau ** 2 * sens.loss_sq_mean
    res = solve_mckp(mckp_groups, budget, method=opts.ip_method,
                     bins=opts.ip_bins)

    assignment = {}
    for group, combo in zip(groups, res.labels):
        for name, fmt in zip(group, combo):
            if fmt != opts.ref_format:
                assignment[name] = fmt

    return MPPlan(
        assignment=assignment,
        groups=groups,
        objective=opts.objective,
        tau=opts.tau,
        budget=float(budget),
        predicted_loss_mse=float(res.d_total),
        predicted_gain=float(res.c_total),
        ip_gap=float(res.gap),
        meta={"n_ops": len(op_index), "n_groups": len(groups),
              "loss_sq_mean": sens.loss_sq_mean,
              "ip_method": res.method},
    )
