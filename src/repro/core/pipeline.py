"""Staged automatic-MP pipeline (paper Algorithm 1), artifact-centric.

The paper's pipeline has one expensive phase and one cheap one:

* **calibrate** — fwd+bwd sensitivity passes over the calibration set
  (Sec. 2.2), partition into sequential sub-graphs (Alg. 2), and per-group
  gain tables for all F^{L_j} combos under every registered gain model
  (Sec. 2.3). Requires the model, its params, and calibration data.
* **solve** — the IP (eq. 5) with budget tau^2 E[g^2]. Pure NumPy over the
  tabulated gains; re-runnable per (tau, objective) in milliseconds.

:func:`calibrate` runs the expensive phase once and returns a durable
:class:`CalibrationBundle` (JSON / npz save-load, like :class:`MPPlan`);
``bundle.solve(tau=..., objective=...)`` replays the IP with no model or
params in scope, and ``bundle.pareto(taus)`` sweeps a tradeoff frontier from
the same artifact. :func:`auto_mixed_precision` remains as the legacy
one-call wrapper (now literally calibrate + solve).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Iterable, Optional, Sequence

import numpy as np

from repro.core import graphs as G
from repro.core.ip_solver import MCKPGroup, solve_mckp
from repro.core.mpconfig import MPPlan
from repro.core.partition import partition_sequential
from repro.core.sensitivity import SensitivityResult, calibrate_sensitivity
from repro.core.timegain import (WallClockGainModel, default_gain_models,
                                 enumerate_combos)
from repro.hw.profiles import TPU_V5E, HWProfile
from repro.quant.formats import get_format

__all__ = ["AMPOptions", "CalibrationBundle", "calibrate",
           "auto_mixed_precision", "predicted_loss_mse", "build_groups",
           "tabulate_measured_gains"]

BUNDLE_SCHEMA = 1


@dataclasses.dataclass
class AMPOptions:
    tau: float = 0.005                    # normalized-RMSE threshold
    formats: tuple = ("bf16", "fp8_e4m3")
    ref_format: str = "bf16"
    objective: str = "ET"                 # ET | TT | M
    max_group_size: int = 8               # cap F^{L_j} enumeration
    drop_residual: bool = True            # paper-faithful
    ip_method: str = "auto"
    ip_bins: int = 8192
    pareto_prune: bool = True             # lossless beyond-paper speedup
    hw: HWProfile = TPU_V5E


def predicted_loss_mse(sens: SensitivityResult, assignment: dict,
                       ref: str = "bf16") -> float:
    """Eq. (6)/(23): additive per-layer loss MSE, d=0 at the reference fmt."""
    return sens.loss_mse(assignment, ref=ref)


def build_groups(model, opts: AMPOptions, quantizable: Optional[set] = None):
    """Partition and return (graph, ordered groups of quantizable op names)."""
    graph = G.build_graph(model)
    groups = partition_sequential(graph, drop_residual=opts.drop_residual,
                                  max_group_size=opts.max_group_size)
    if quantizable is not None:
        groups = [[n for n in g if n in quantizable] for g in groups]
        groups = [g for g in groups if g]
    return graph, groups


def _params_fingerprint(params) -> str:
    """Cheap content fingerprint to invalidate cached bundles on new params."""
    import jax
    import jax.numpy as jnp
    n = 0
    acc = 0.0
    for leaf in jax.tree_util.tree_leaves(params):
        arr = jnp.asarray(leaf)
        n += int(arr.size)
        acc += float(jnp.sum(jnp.abs(arr).astype(jnp.float32)))
    return f"{n}:{acc:.6e}"


@dataclasses.dataclass
class CalibrationBundle:
    """Everything the IP needs, detached from the model: the paper's
    expensive calibration phase as a durable artifact.

    ``objectives`` maps objective name -> ``{"groups": [[op name, ...], ...],
    "gains": [np.ndarray of len F^{L_j} per group]}``; gain rows are indexed
    by :func:`~repro.core.timegain.enumerate_combos` order over ``formats``,
    so combos are regenerated deterministically at solve time instead of
    being stored.
    """

    sens: SensitivityResult
    formats: tuple                     # e.g. ("bf16", "fp8_e4m3")
    ref_format: str
    objectives: dict                   # objective -> {"groups": ..., "gains": ...}
    default_tau: float = 0.005
    default_objective: str = "ET"
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.formats = tuple(self.formats)
        for entry in self.objectives.values():
            entry["groups"] = [list(g) for g in entry["groups"]]
            entry["gains"] = [np.asarray(g, np.float64) for g in entry["gains"]]

    # ---- introspection ---------------------------------------------------
    @property
    def op_names(self) -> list:
        return [op.name for op in self.sens.ops]

    def unknown_ops(self, known_ops) -> set:
        """Calibrated op names that do not exist in ``known_ops``.

        The serving launcher checks this before solving from a bundle: a
        non-empty result means the bundle was calibrated on a different model
        (or op namespace) and its plans would silently not apply.
        """
        known = set(known_ops)
        return {n for n in self.op_names if n not in known}

    # ---- the cheap phase: IP solves over the tabulated gains -------------
    def solve(self, tau: Optional[float] = None,
              objective: Optional[str] = None, *,
              budget: Optional[float] = None, ip_method: str = "auto",
              ip_bins: int = 8192) -> MPPlan:
        """Solve the IP (eq. 5) for one (tau, objective). Pure NumPy: no
        model, params, or calibration data required."""
        tau = self.default_tau if tau is None else tau
        objective = objective or self.default_objective
        if objective not in self.objectives:
            raise KeyError(
                f"objective {objective!r} not calibrated; bundle has "
                f"{sorted(self.objectives)}")
        # measured tier: a tabulated "<obj>_wall" table (see
        # tabulate_measured_gains) prices plans with measured wall-clock
        # gains instead of the analytic tables for the same objective; the
        # plan meta records which tier actually priced it so a production
        # solve falling back to roofline gains is visible.
        table_key = objective
        if f"{objective}_wall" in self.objectives:
            table_key = f"{objective}_wall"
        if table_key.endswith("_wall"):
            gain_tier = "measured"
        elif objective == "ET":
            gain_tier = "roofline_fallback"
        else:
            gain_tier = "analytic"
        entry = self.objectives[table_key]
        groups, tables = entry["groups"], entry["gains"]

        mckp_groups = []
        for gi, (group, c) in enumerate(zip(groups, tables)):
            combos = enumerate_combos(len(group), self.formats)
            d = np.array([
                sum(0.0 if f == self.ref_format else
                    self.sens.sensitivity.get(name, 0.0) * get_format(f).alpha
                    for name, f in zip(group, combo))
                for combo in combos])
            mckp_groups.append(MCKPGroup(name=f"group_{gi}", labels=combos,
                                         c=c, d=d))

        if budget is None:
            budget = tau ** 2 * self.sens.loss_sq_mean
        res = solve_mckp(mckp_groups, budget, method=ip_method, bins=ip_bins)

        assignment = {}
        for group, combo in zip(groups, res.labels):
            for name, fmt in zip(group, combo):
                if fmt != self.ref_format:
                    assignment[name] = fmt

        return MPPlan(
            assignment=assignment,
            groups=[list(g) for g in groups],
            objective=objective,
            tau=float(tau),
            budget=float(budget),
            predicted_loss_mse=float(res.d_total),
            predicted_gain=float(res.c_total),
            ip_gap=float(res.gap),
            meta={"n_ops": len(self.sens.ops), "n_groups": len(groups),
                  "loss_sq_mean": self.sens.loss_sq_mean,
                  "ip_method": res.method,
                  "gain_tier": gain_tier, "gain_table": table_key},
        )

    def pareto(self, taus: Sequence[float], objective: Optional[str] = None,
               **solve_kw) -> list:
        """One plan per tau — the paper's Fig. 4 tradeoff frontier from a
        single calibration."""
        return [self.solve(tau=t, objective=objective, **solve_kw)
                for t in taus]

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        return {
            "schema": BUNDLE_SCHEMA,
            "sens": self.sens.to_dict(),
            "formats": list(self.formats),
            "ref_format": self.ref_format,
            "objectives": {
                obj: {"groups": [list(g) for g in entry["groups"]],
                      "gains": [np.asarray(t).tolist()
                                for t in entry["gains"]]}
                for obj, entry in self.objectives.items()},
            "default_tau": float(self.default_tau),
            "default_objective": self.default_objective,
            "meta": self.meta,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationBundle":
        schema = d.get("schema", BUNDLE_SCHEMA)
        if schema > BUNDLE_SCHEMA:
            raise ValueError(f"bundle schema {schema} is newer than "
                             f"supported {BUNDLE_SCHEMA}")
        return cls(sens=SensitivityResult.from_dict(d["sens"]),
                   formats=tuple(d["formats"]),
                   ref_format=d["ref_format"],
                   objectives={obj: {"groups": entry["groups"],
                                     "gains": entry["gains"]}
                               for obj, entry in d["objectives"].items()},
                   default_tau=float(d.get("default_tau", 0.005)),
                   default_objective=d.get("default_objective", "ET"),
                   meta=dict(d.get("meta", {})))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "CalibrationBundle":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        """``.npz`` -> binary gain tables + JSON header; else plain JSON."""
        path = str(path)
        if path.endswith(".npz"):
            d = self.to_dict()
            arrays = {}
            for obj, entry in d["objectives"].items():
                for gi, table in enumerate(entry["gains"]):
                    arrays[f"gains::{obj}::{gi}"] = np.asarray(table,
                                                               np.float64)
                entry["gains"] = len(entry["gains"])  # count placeholder
            header = json.dumps(d, sort_keys=True).encode("utf-8")
            arrays["header"] = np.frombuffer(header, np.uint8)
            with open(path, "wb") as f:
                np.savez_compressed(f, **arrays)
        else:
            with open(path, "w") as f:
                f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "CalibrationBundle":
        path = str(path)
        if path.endswith(".npz"):
            with np.load(path) as z:
                d = json.loads(bytes(z["header"].tobytes()).decode("utf-8"))
                for obj, entry in d["objectives"].items():
                    entry["gains"] = [z[f"gains::{obj}::{gi}"]
                                      for gi in range(int(entry["gains"]))]
                return cls.from_dict(d)
        with open(path) as f:
            return cls.from_json(f.read())


def _calib_hash(batches) -> Optional[str]:
    """Content hash of the calibration set (array bytes, order-sensitive).

    Keys registry lookups and cache validation: two bundles for the same
    checkpoint calibrated on different data are different artifacts."""
    if batches is None:
        return None
    import hashlib
    h = hashlib.sha256()
    for batch in batches:
        for key in sorted(batch):
            v = np.asarray(batch[key])
            h.update(key.encode("utf-8"))
            h.update(str(v.shape).encode("utf-8"))
            h.update(np.ascontiguousarray(v).tobytes())
    return h.hexdigest()[:16]


def tabulate_measured_gains(bundle: CalibrationBundle, run_factory: Callable,
                            *, objective: str = "ET", n_iters: int = 5,
                            n_warmup: int = 2) -> str:
    """Measure per-group wall-clock gains (paper Sec. 2.3.1) and tabulate
    them into ``bundle.objectives["<objective>_wall"]`` over the same groups
    as the analytic ``objective`` tables.

    Once tabulated (and persisted via ``bundle.save``), every
    ``bundle.solve(objective=...)`` for that objective automatically prices
    plans with the measured gains — the production tier — and stamps
    ``plan.meta["gain_tier"] = "measured"``; bundles without the table keep
    solving from the analytic gains with ``"roofline_fallback"`` flagged.

    ``run_factory(assignment)`` must return a zero-arg callable executing one
    end-to-end step (e.g. a compiled serving prefill) under the given
    op->format assignment (see :class:`~repro.core.timegain.WallClockGainModel`).
    Returns the objective key the table was stored under.
    """
    if objective.endswith("_wall"):
        raise ValueError(f"objective {objective!r} is already a measured tier")
    if objective not in bundle.objectives:
        raise KeyError(
            f"objective {objective!r} not calibrated; bundle has "
            f"{sorted(bundle.objectives)}")
    gm = WallClockGainModel(run_factory, n_iters=n_iters, n_warmup=n_warmup)
    op_index = {op.name: op for op in bundle.sens.ops}
    groups = bundle.objectives[objective]["groups"]
    tables = []
    for group in groups:
        ops = [op_index[n] for n in group]
        combos = enumerate_combos(len(ops), bundle.formats)
        tables.append(np.asarray(gm.gains(ops, combos), np.float64))
    key = f"{objective}_wall"
    bundle.objectives[key] = {"groups": [list(g) for g in groups],
                              "gains": tables}
    bundle.meta.setdefault("gain_models", {})[key] = type(gm).__name__
    return key


def _cache_hit(bundle: CalibrationBundle, opts: AMPOptions,
               fingerprint: str, gain_models: dict,
               calib_hash: Optional[str] = None) -> bool:
    """A cached bundle is reusable iff it was calibrated with the same
    formats, partition options, params content, calibration set, and its
    gain tables come from the same gain-model type per requested objective
    (a bundle of roofline tables must not satisfy a WallClockGainModel
    request)."""
    meta = bundle.meta
    recorded = meta.get("gain_models", {})
    cached_ch = meta.get("calib_hash")
    return (bundle.formats == tuple(opts.formats)
            and bundle.ref_format == opts.ref_format
            and meta.get("max_group_size") == opts.max_group_size
            and meta.get("drop_residual") == opts.drop_residual
            and meta.get("hw") == opts.hw.name  # gain tables are hw-specific
            and meta.get("params_fingerprint") == fingerprint
            # pre-calib_hash artifacts (or sens-injected runs) stay valid
            and (cached_ch is None or calib_hash is None
                 or cached_ch == calib_hash)
            and set(gain_models) <= set(bundle.objectives)
            and all(recorded.get(obj) == type(gm).__name__
                    for obj, gm in gain_models.items()))


def calibrate(model, params, calib_batches: Optional[Iterable],
              opts: Optional[AMPOptions] = None, *,
              gain_models: Optional[dict] = None,
              sens: Optional[SensitivityResult] = None,
              loss_fn: Optional[Callable] = None,
              cache: Optional[str] = None) -> CalibrationBundle:
    """The expensive phase of Algorithm 1, run once per (model, params).

    Stages: (1) sensitivity calibration over ``calib_batches`` — skipped when
    a precomputed ``sens`` is injected; (2) partition into sequential
    sub-graphs; (3) per-group gain tables for every model in ``gain_models``
    (default: the Sec. 2.3 registry — ET roofline, TT theoretical, M memory).

    ``cache``: path of a saved bundle. If it exists and matches (same
    formats, partition options, params fingerprint, and objectives), it is
    loaded and returned without touching the model — making repeated
    calibration calls resumable; otherwise calibration runs and the result
    is saved there.
    """
    opts = opts or AMPOptions()
    if gain_models is None:
        gain_models = default_gain_models(opts.hw, ref=opts.ref_format)

    fingerprint = _params_fingerprint(params)
    if calib_batches is not None:
        calib_batches = list(calib_batches)
    calib_hash = _calib_hash(calib_batches)
    if cache and os.path.exists(cache):
        try:
            cached = CalibrationBundle.load(cache)
        except Exception:
            cached = None
        if cached is not None and _cache_hit(cached, opts, fingerprint,
                                             gain_models, calib_hash):
            # solve defaults are caller convenience, not part of the artifact
            cached.default_tau = opts.tau
            cached.default_objective = opts.objective
            return cached

    loss_fn = loss_fn or (lambda p, b, ctx: model.loss(p, b, ctx))

    # ---- Alg.1 line 2: sensitivity calibration ----
    if sens is None:
        sens = calibrate_sensitivity(loss_fn, params, calib_batches)
    op_index = {op.name: op for op in sens.ops}

    # ---- Alg.1 line 1: partition (once; filtered per objective) ----
    graph = G.build_graph(model)
    base_groups = partition_sequential(graph, drop_residual=opts.drop_residual,
                                       max_group_size=opts.max_group_size)

    def groups_for(quantizable: set) -> list:
        groups = [[n for n in g if n in quantizable] for g in base_groups]
        return [g for g in groups if g]

    # ---- Alg.1 line 3: per-group gain tables for every registered model ----
    objectives = {}
    for objective, gain_model in gain_models.items():
        if objective == "M":
            # memory is additive per layer and quantizes linear layers only:
            # trivial per-layer groups (Sec. 2.3.3)
            quantizable = {n for n, op in op_index.items()
                           if op.kind == "linear"}
            groups = [[n] for g in groups_for(quantizable) for n in g]
        else:
            groups = groups_for(set(op_index))
        tables = []
        for group in groups:
            ops = [op_index[n] for n in group]
            combos = enumerate_combos(len(ops), opts.formats)
            tables.append(np.asarray(gain_model.gains(ops, combos),
                                     np.float64))
        objectives[objective] = {"groups": groups, "gains": tables}

    bundle = CalibrationBundle(
        sens=sens,
        formats=tuple(opts.formats),
        ref_format=opts.ref_format,
        objectives=objectives,
        default_tau=opts.tau,
        default_objective=opts.objective,
        meta={"max_group_size": opts.max_group_size,
              "drop_residual": opts.drop_residual,
              "hw": opts.hw.name,
              "params_fingerprint": fingerprint,
              "calib_hash": calib_hash,
              "n_calib_batches": sens.n_batches,
              "gain_models": {obj: type(gm).__name__
                              for obj, gm in gain_models.items()},
              "arch": getattr(getattr(model, "cfg", None), "name", None)},
    )
    if cache:
        bundle.save(cache)
    return bundle


def auto_mixed_precision(model, params, calib_batches: Iterable,
                         opts: AMPOptions, gain_model=None,
                         sens: Optional[SensitivityResult] = None,
                         loss_fn: Optional[Callable] = None) -> MPPlan:
    """Legacy one-call API: calibrate then solve. Prefer the staged API when
    sweeping (tau, objective) — calibration dominates the cost and a
    :class:`CalibrationBundle` amortizes it across solves."""
    if gain_model is None:
        gain_model = default_gain_models(opts.hw,
                                         ref=opts.ref_format)[opts.objective]
    bundle = calibrate(model, params, calib_batches, opts,
                       gain_models={opts.objective: gain_model},
                       sens=sens, loss_fn=loss_fn)
    return bundle.solve(tau=opts.tau, objective=opts.objective,
                        ip_method=opts.ip_method, ip_bins=opts.ip_bins)
