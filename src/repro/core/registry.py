"""Bundle registry: calibration artifacts keyed by architecture, checkpoint
fingerprint, and calibration-set hash, with serve-time selection of the
freshest compatible bundle.

A :class:`~repro.core.pipeline.CalibrationBundle` already records everything
needed to decide compatibility (``meta["arch"]``,
``meta["params_fingerprint"]``, ``meta["calib_hash"]``); the registry is a
directory convention over those keys::

    <root>/<arch>/<fingerprint>/bundle-0000.npz
    <root>/<arch>/<fingerprint>/bundle-0001.npz     # newer calibration
    ...

``put(bundle)`` files an artifact under its own keys; ``find(arch,
fingerprint)`` returns the freshest artifact whose keys match, verifying the
loaded header against the directory it was found in (a hand-copied bundle in
the wrong slot is rejected, not silently served). ``launch/serve.py
--registry`` uses this to pick the bundle for the checkpoint it is actually
serving instead of trusting a hand-passed path.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.core.pipeline import CalibrationBundle

__all__ = ["BundleRegistry"]


def _safe(component: str) -> str:
    """Filesystem-safe directory name for a key component."""
    return "".join(c if (c.isalnum() or c in "._-+") else "_"
                   for c in str(component))


class BundleRegistry:
    """Directory-backed registry of calibration bundles.

    Freshness is decided by file mtime (name as a deterministic tiebreak),
    so re-calibrating the same (arch, checkpoint) simply files a new artifact
    that future ``find`` calls prefer — no in-place overwrites.
    """

    def __init__(self, root: str):
        self.root = str(root)

    # ---- layout ----------------------------------------------------------
    def _dir(self, arch: str, fingerprint: str) -> str:
        return os.path.join(self.root, _safe(arch), _safe(fingerprint))

    def entries(self) -> list:
        """All (arch_dir, fingerprint_dir, path) triples on disk, unloaded."""
        out = []
        if not os.path.isdir(self.root):
            return out
        for arch in sorted(os.listdir(self.root)):
            adir = os.path.join(self.root, arch)
            if not os.path.isdir(adir):
                continue
            for fp in sorted(os.listdir(adir)):
                fdir = os.path.join(adir, fp)
                if not os.path.isdir(fdir):
                    continue
                for name in sorted(os.listdir(fdir)):
                    if name.endswith((".npz", ".json")):
                        out.append((arch, fp, os.path.join(fdir, name)))
        return out

    # ---- write -----------------------------------------------------------
    def put(self, bundle: CalibrationBundle, *, fmt: str = "npz") -> str:
        """File ``bundle`` under its own (arch, fingerprint) keys; returns
        the artifact path. Never overwrites: each put gets a fresh name."""
        arch = bundle.meta.get("arch")
        fingerprint = bundle.meta.get("params_fingerprint")
        if not arch or not fingerprint:
            raise ValueError(
                "bundle.meta lacks arch/params_fingerprint — calibrate() "
                "stamps both; a registry cannot key an anonymous bundle")
        d = self._dir(arch, fingerprint)
        os.makedirs(d, exist_ok=True)
        n = 0
        while True:
            path = os.path.join(d, f"bundle-{n:04d}.{fmt}")
            if not os.path.exists(path):
                break
            n += 1
        bundle.save(path)
        return path

    # ---- read ------------------------------------------------------------
    def find(self, arch: str, params_fingerprint: str,
             calib_hash: Optional[str] = None) -> CalibrationBundle:
        """Freshest compatible bundle for (arch, checkpoint [, calib set]).

        Candidates come from the keyed directory, newest mtime first; each
        is loaded and its *header* keys verified against the request (and
        against ``calib_hash`` when given — bundles predating calib hashes
        match any). Raises ``LookupError`` naming what the registry does
        hold when nothing matches.
        """
        d = self._dir(arch, params_fingerprint)
        candidates = []
        if os.path.isdir(d):
            for name in os.listdir(d):
                if name.endswith((".npz", ".json")):
                    path = os.path.join(d, name)
                    candidates.append((os.path.getmtime(path), name, path))
        rejected = []
        for _, _, path in sorted(candidates, reverse=True):
            try:
                bundle = CalibrationBundle.load(path)
            except Exception as e:
                # a corrupted artifact (truncated npz, bad JSON, partial
                # write) must not take the whole registry down: warn loudly
                # at skip time and fall through to the next-freshest
                # candidate, keeping the detail for the final LookupError
                print(f"[registry] warning: skipping corrupted bundle "
                      f"{path}: {e}")
                rejected.append(f"{path}: unreadable ({e})")
                continue
            meta = bundle.meta
            if meta.get("arch") != arch:
                rejected.append(f"{path}: header arch {meta.get('arch')!r} "
                                f"!= {arch!r}")
                continue
            if meta.get("params_fingerprint") != params_fingerprint:
                rejected.append(
                    f"{path}: header fingerprint "
                    f"{meta.get('params_fingerprint')!r} != "
                    f"{params_fingerprint!r}")
                continue
            if (calib_hash is not None
                    and meta.get("calib_hash") is not None
                    and meta.get("calib_hash") != calib_hash):
                rejected.append(f"{path}: calib_hash "
                                f"{meta.get('calib_hash')!r} != "
                                f"{calib_hash!r}")
                continue
            return bundle
        have = [f"{a}/{fp}" for a, fp, _ in self.entries()]
        detail = "; ".join(rejected) if rejected else "no candidates"
        raise LookupError(
            f"no compatible bundle for arch={arch!r} "
            f"fingerprint={params_fingerprint!r}"
            + (f" calib_hash={calib_hash!r}" if calib_hash else "")
            + f" under {self.root} ({detail}); registry holds: "
            + (", ".join(sorted(set(have))) if have else "nothing"))
