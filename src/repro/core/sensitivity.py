"""Per-layer sensitivity calibration (paper Sec. 2.2, eqs. 17-22).

For every quantizable op ``l`` with extended input ``z_l`` (activations and
weights of a linear layer, or both operands of a BGEMM), the sensitivity is

    s_l = (1/R) sum_r || z_l^r (.) dg/dz_l^r ||^2                    (19, 21)

and the loss-MSE contribution of executing that op in format ``f`` is

    d_{l,f} = s_l * alpha_f,   alpha_f = 2^(-2 m_f)/12               (20, 22)

Implementation: every quantizable op perturbs its operands with zero-valued
*probe* arrays ``(z + p)``; ``jax.grad`` w.r.t. the probe pytree returns the
elementwise ``dg/dz`` at each use site, and a forward capture provides ``z``.
``s_l`` is then accumulated over calibration batches. The only calibration
memory overhead is one activation-sized probe per op (no optimizer state),
matching the paper's claim.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import get_format
from repro.quant.qops import OpInfo, QuantContext

__all__ = ["SensitivityResult", "collect_ops", "calibrate_sensitivity"]


@dataclasses.dataclass
class SensitivityResult:
    """Calibrated statistics over R calibration samples."""

    sensitivity: dict          # op name -> s_l (float)
    loss_sq_mean: float        # E[g^2]
    loss_mean: float           # E[g]
    n_batches: int
    ops: list                  # list[OpInfo] (from registry tracing)

    def loss_mse(self, assignment: dict, default: str = "bf16") -> float:
        """Predicted loss MSE of an MP assignment (eq. 23): sum_l s_l alpha_f."""
        total = 0.0
        for name, s in self.sensitivity.items():
            fmt = get_format(assignment.get(name, default))
            total += s * fmt.alpha
        return total

    def d_layer(self, name: str, fmt_name: str) -> float:
        """d_{l,f} = s_l * alpha_f (eq. 22)."""
        return self.sensitivity[name] * get_format(fmt_name).alpha


def collect_ops(loss_fn: Callable, params, batch) -> list:
    """Trace the model once (abstractly) and return every quantizable OpInfo.

    ``loss_fn(params, batch, ctx)`` must route all quantizable matmuls
    through ``repro.quant.qops``.
    """
    registry: list = []
    ctx = QuantContext(mode="plain", registry=registry)
    jax.eval_shape(lambda p, b: loss_fn(p, b, ctx), params, batch)
    # deduplicate call sites hit multiple times (e.g. loss chunks)
    seen, out = set(), []
    for op in registry:
        if op.name not in seen:
            seen.add(op.name)
            out.append(op)
    return out


def _zero_probes(loss_fn, params, batch, ops: Iterable[OpInfo]) -> dict:
    """Zero probe arrays shaped like each op's operands for this batch."""
    shapes = {}
    registry: list = []
    ctx = QuantContext(mode="plain", registry=registry)
    jax.eval_shape(lambda p, b: loss_fn(p, b, ctx), params, batch)
    for op in registry:
        if op.name not in shapes:
            shapes[op.name] = (op.lhs_shape, op.rhs_shape)
    names = {op.name for op in ops}
    return {name: (jnp.zeros(lhs, jnp.float32), jnp.zeros(rhs, jnp.float32))
            for name, (lhs, rhs) in shapes.items() if name in names}


def calibrate_sensitivity(loss_fn: Callable, params, batches: Iterable,
                          ops: Optional[list] = None,
                          op_chunk: Optional[int] = None) -> SensitivityResult:
    """Run forward+backward over calibration batches; returns s_l per op.

    ``op_chunk``: process ops in groups of this size (bounds probe-gradient
    memory for big models at the cost of repeated backward passes).
    """
    first = True
    sens: dict = {}
    loss_sum = 0.0
    loss_sq_sum = 0.0
    n = 0

    def probed_loss(probes, p, b):
        ctx = QuantContext(mode="probe", probes=probes, captures={})
        loss = loss_fn(p, b, ctx)
        return loss, ctx.captures

    grad_fn = jax.jit(jax.value_and_grad(probed_loss, has_aux=True))

    for batch in batches:
        if first:
            if ops is None:
                ops = collect_ops(loss_fn, params, batch)
            first = False
        groups = [ops]
        if op_chunk is not None:
            groups = [ops[i:i + op_chunk] for i in range(0, len(ops), op_chunk)]
        loss_val = None
        for group in groups:
            probes = _zero_probes(loss_fn, params, batch, group)
            (loss_val, captures), grads = grad_fn(probes, params, batch)
            for name in probes:
                z_lhs, z_rhs = captures[name]
                g_lhs, g_rhs = grads[name]
                s = (jnp.sum(jnp.square(z_lhs.astype(jnp.float32)
                                        * g_lhs.astype(jnp.float32)))
                     + jnp.sum(jnp.square(z_rhs.astype(jnp.float32)
                                          * g_rhs.astype(jnp.float32))))
                sens[name] = sens.get(name, 0.0) + float(s)
        loss_sum += float(loss_val)
        loss_sq_sum += float(loss_val) ** 2
        n += 1

    assert n > 0, "no calibration batches"
    return SensitivityResult(
        sensitivity={k: v / n for k, v in sens.items()},
        loss_sq_mean=loss_sq_sum / n,
        loss_mean=loss_sum / n,
        n_batches=n,
        ops=list(ops),
    )
