"""Per-layer sensitivity calibration (paper Sec. 2.2, eqs. 17-22).

For every quantizable op ``l`` with extended input ``z_l`` (activations and
weights of a linear layer, or both operands of a BGEMM), the sensitivity is

    s_l = (1/R) sum_r || z_l^r (.) dg/dz_l^r ||^2                    (19, 21)

and the loss-MSE contribution of executing that op in format ``f`` is

    d_{l,f} = s_l * alpha_f,   alpha_f = 2^(-2 m_f)/12               (20, 22)

Implementation: every quantizable op perturbs its operands with zero-valued
*probe* arrays ``(z + p)``; ``jax.grad`` w.r.t. the probe pytree returns the
elementwise ``dg/dz`` at each use site, and a forward capture provides ``z``.
``s_l`` is then accumulated over calibration batches. The only calibration
memory overhead is one activation-sized probe per op (no optimizer state),
matching the paper's claim.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import get_format
from repro.quant.qops import OpInfo, QuantContext

__all__ = ["SensitivityResult", "collect_ops", "calibrate_sensitivity"]


@dataclasses.dataclass
class SensitivityResult:
    """Calibrated statistics over R calibration samples."""

    sensitivity: dict          # op name -> s_l (float)
    loss_sq_mean: float        # E[g^2]
    loss_mean: float           # E[g]
    n_batches: int
    ops: list                  # list[OpInfo] (from registry tracing)

    def loss_mse(self, assignment: dict, ref: str = "bf16") -> float:
        """Predicted loss MSE of an MP assignment (eq. 23).

        Eq. (23) measures noise *added* relative to the reference run, so an
        op executed at the reference format contributes d = 0 — not
        ``s_l * alpha_ref``. Ops absent from ``assignment`` stay at the
        reference format. This is the single implementation behind
        ``pipeline.predicted_loss_mse`` and the IP's per-combo d vectors.
        """
        total = 0.0
        for name, fmt in assignment.items():
            if fmt == ref:
                continue
            total += self.sensitivity.get(name, 0.0) * get_format(fmt).alpha
        return total

    def d_layer(self, name: str, fmt_name: str) -> float:
        """d_{l,f} = s_l * alpha_f (eq. 22)."""
        return self.sensitivity[name] * get_format(fmt_name).alpha

    def to_dict(self) -> dict:
        return {
            "sensitivity": dict(self.sensitivity),
            "loss_sq_mean": float(self.loss_sq_mean),
            "loss_mean": float(self.loss_mean),
            "n_batches": int(self.n_batches),
            "ops": [dataclasses.asdict(op) for op in self.ops],
        }

    @classmethod
    def from_dict(cls, d: dict) -> "SensitivityResult":
        ops = [OpInfo(name=o["name"], kind=o["kind"], spec=o["spec"],
                      lhs_shape=tuple(o["lhs_shape"]),
                      rhs_shape=tuple(o["rhs_shape"]),
                      out_shape=tuple(o["out_shape"]),
                      macs=int(o["macs"]),
                      weight_elems=int(o["weight_elems"]))
               for o in d["ops"]]
        return cls(sensitivity=dict(d["sensitivity"]),
                   loss_sq_mean=float(d["loss_sq_mean"]),
                   loss_mean=float(d["loss_mean"]),
                   n_batches=int(d["n_batches"]), ops=ops)


def _trace_ops(loss_fn: Callable, params, batch) -> list:
    """One abstract trace; quantizable OpInfo per call site, deduplicated."""
    registry: list = []
    ctx = QuantContext(mode="plain", registry=registry)
    jax.eval_shape(lambda p, b: loss_fn(p, b, ctx), params, batch)
    # deduplicate call sites hit multiple times (e.g. loss chunks)
    seen, out = set(), []
    for op in registry:
        if op.name not in seen:
            seen.add(op.name)
            out.append(op)
    return out


def collect_ops(loss_fn: Callable, params, batch) -> list:
    """Trace the model once (abstractly) and return every quantizable OpInfo.

    ``loss_fn(params, batch, ctx)`` must route all quantizable matmuls
    through ``repro.quant.qops``.
    """
    return _trace_ops(loss_fn, params, batch)


def _batch_signature(batch) -> tuple:
    """Hashable key describing a batch's pytree structure and leaf shapes."""
    leaves, treedef = jax.tree_util.tree_flatten(batch)
    return (treedef, tuple((tuple(getattr(l, "shape", ())),
                            str(jnp.result_type(l))) for l in leaves))


def _zero_probes(shapes: dict, ops: Iterable[OpInfo]) -> dict:
    """Zero probe arrays shaped like each op's operands.

    ``shapes`` maps op name -> (lhs_shape, rhs_shape) from a cached trace.
    """
    return {op.name: (jnp.zeros(shapes[op.name][0], jnp.float32),
                      jnp.zeros(shapes[op.name][1], jnp.float32))
            for op in ops if op.name in shapes}


def calibrate_sensitivity(loss_fn: Callable, params, batches: Iterable,
                          ops: Optional[list] = None,
                          op_chunk: Optional[int] = None) -> SensitivityResult:
    """Run forward+backward over calibration batches; returns s_l per op.

    ``op_chunk``: process ops in groups of this size (bounds probe-gradient
    memory for big models at the cost of repeated backward passes).
    """
    first = True
    sens: dict = {}
    loss_sum = 0.0
    loss_sq_sum = 0.0
    n = 0

    def probed_loss(probes, p, b):
        ctx = QuantContext(mode="probe", probes=probes, captures={})
        loss = loss_fn(p, b, ctx)
        return loss, ctx.captures

    grad_fn = jax.jit(jax.value_and_grad(probed_loss, has_aux=True))

    # Probe shapes only depend on the batch's shape signature, so one trace
    # per *distinct* signature serves every op-chunk of every batch (steady
    # state: one trace total). The first trace doubles as op collection.
    shape_cache: dict = {}

    def shapes_for(batch) -> tuple:
        sig = _batch_signature(batch)
        if sig not in shape_cache:
            traced = _trace_ops(loss_fn, params, batch)
            shape_cache[sig] = (traced, {op.name: (op.lhs_shape, op.rhs_shape)
                                         for op in traced})
        return shape_cache[sig]

    for batch in batches:
        traced, shapes = shapes_for(batch)
        if first:
            if ops is None:
                ops = traced
            first = False
        groups = [ops]
        if op_chunk is not None:
            groups = [ops[i:i + op_chunk] for i in range(0, len(ops), op_chunk)]
        loss_val = None
        for group in groups:
            probes = _zero_probes(shapes, group)
            (loss_val, captures), grads = grad_fn(probes, params, batch)
            for name in probes:
                z_lhs, z_rhs = captures[name]
                g_lhs, g_rhs = grads[name]
                s = (jnp.sum(jnp.square(z_lhs.astype(jnp.float32)
                                        * g_lhs.astype(jnp.float32)))
                     + jnp.sum(jnp.square(z_rhs.astype(jnp.float32)
                                          * g_rhs.astype(jnp.float32))))
                sens[name] = sens.get(name, 0.0) + float(s)
        loss_sum += float(loss_val)
        loss_sq_sum += float(loss_val) ** 2
        n += 1

    assert n > 0, "no calibration batches"
    return SensitivityResult(
        sensitivity={k: v / n for k, v in sens.items()},
        loss_sq_mean=loss_sq_sum / n,
        loss_mean=loss_sum / n,
        n_batches=n,
        ops=list(ops),
    )
