"""Performance metrics c (paper Sec. 2.3): empirical / theoretical / memory.

All gain models share one interface::

    gains(group_ops, combos) -> np.ndarray  # gained quantity per combo

where ``group_ops`` is a list of OpInfo and ``combos`` a list of per-op
format tuples. Positive = improvement over the all-BF16 reference.

* TheoreticalGainModel — eq. (24): MACs x per-MAC time gain delta_T,f.
* MemoryGainModel      — eq. (25): weight elements x byte reduction delta_M,f
                         (linear layers only; BGEMM operands are transient).
* RooflineGainModel    — TPU-adapted ET tier for environments without the
  target accelerator: per-op time = max(compute, HBM) roofline at the op's
  formats (+ activation-requant overhead), summed within the group. On a
  single-stream TPU core the group structure captures fusion boundaries
  rather than engine concurrency — see DESIGN.md "hardware adaptation".
* WallClockGainModel   — the paper's actual method: measure end-to-end TTFT
  with group j set to combo p and everything else BF16, subtract from the
  all-BF16 TTFT (Sec. 2.3.1). Runs on whatever JAX backend is attached.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.hw.profiles import HWProfile
from repro.quant.formats import get_format
from repro.quant.qops import OpInfo

__all__ = [
    "enumerate_combos", "default_gain_models", "TheoreticalGainModel",
    "MemoryGainModel", "RooflineGainModel", "WallClockGainModel",
]


def enumerate_combos(n_ops: int, formats: Sequence[str]) -> list:
    """All F^L format tuples for a group of L ops."""
    return list(itertools.product(formats, repeat=n_ops))


def default_gain_models(hw: HWProfile, ref: str = "bf16") -> dict:
    """The registered objective -> gain-model map (paper Sec. 2.3).

    Calibration tabulates per-group gains for every model in this registry so
    a :class:`~repro.core.pipeline.CalibrationBundle` can solve any objective
    later without the model in scope. WallClockGainModel is deliberately not
    registered: it needs a live run factory (pass it explicitly instead).
    """
    return {"ET": RooflineGainModel(hw, ref=ref),
            "TT": TheoreticalGainModel(hw, ref=ref),
            "M": MemoryGainModel(ref=ref)}


class TheoreticalGainModel:
    """c^TT (eq. 24): additive per layer by construction."""

    def __init__(self, hw: HWProfile, ref: str = "bf16"):
        self.hw = hw
        self.ref = ref

    def op_gain(self, op: OpInfo, fmt: str) -> float:
        return op.macs * self.hw.delta_T(fmt, self.ref)

    def gains(self, group_ops: Sequence[OpInfo], combos: Sequence) -> np.ndarray:
        return np.array([
            sum(self.op_gain(op, f) for op, f in zip(group_ops, combo))
            for combo in combos])


class MemoryGainModel:
    """c^M (eq. 25): bytes saved in persistent weights; BGEMM contributes 0."""

    def __init__(self, ref: str = "bf16"):
        self.ref_bytes = get_format(ref).bytes

    def op_gain(self, op: OpInfo, fmt: str) -> float:
        if op.kind != "linear":
            return 0.0
        return op.weight_elems * (self.ref_bytes - get_format(fmt).bytes)

    def gains(self, group_ops: Sequence[OpInfo], combos: Sequence) -> np.ndarray:
        return np.array([
            sum(self.op_gain(op, f) for op, f in zip(group_ops, combo))
            for combo in combos])


class RooflineGainModel:
    """Roofline-estimated execution-time gain on the target accelerator."""

    def __init__(self, hw: HWProfile, ref: str = "bf16",
                 requant_overhead: bool = True, out_bytes: float = 2.0):
        self.hw = hw
        self.ref = ref
        self.requant_overhead = requant_overhead
        self.out_bytes = out_bytes

    def _elems(self, shape) -> int:
        n = 1
        for s in shape:
            n *= int(s)
        return n

    def op_time(self, op: OpInfo, fmt: str) -> float:
        fb = get_format(fmt).bytes
        lhs, rhs = self._elems(op.lhs_shape), self._elems(op.rhs_shape)
        out = self._elems(op.out_shape)
        bytes_moved = lhs * fb + rhs * fb + out * self.out_bytes
        if self.requant_overhead and fmt != self.ref:
            # activations arrive in bf16 and must be cast (read ref + write f)
            act = lhs if op.kind == "linear" else lhs + rhs
            bytes_moved += act * (get_format(self.ref).bytes + fb)
        t_compute = 2.0 * op.macs / self.hw.flops(fmt)
        t_memory = bytes_moved / self.hbm_bw
        return max(t_compute, t_memory)

    @property
    def hbm_bw(self) -> float:
        return self.hw.hbm_bw

    def gains(self, group_ops: Sequence[OpInfo], combos: Sequence) -> np.ndarray:
        t_ref = sum(self.op_time(op, self.ref) for op in group_ops)
        return np.array([
            t_ref - sum(self.op_time(op, f) for op, f in zip(group_ops, combo))
            for combo in combos])


@dataclasses.dataclass
class WallClockGainModel:
    """The paper's empirical method. ``run_factory(assignment)`` must return
    a zero-arg callable executing one end-to-end step (e.g. compiled prefill)
    under the given op->format assignment; everything not in the assignment
    stays at the reference format.
    """

    run_factory: Callable            # assignment dict -> () -> None
    n_iters: int = 5                 # the paper averages 5 iterations
    n_warmup: int = 2

    _base_time: Optional[float] = None

    def _time(self, assignment: dict) -> float:
        fn = self.run_factory(assignment)
        for _ in range(self.n_warmup):
            fn()
        ts = []
        for _ in range(self.n_iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def base_time(self) -> float:
        if self._base_time is None:
            self._base_time = self._time({})
        return self._base_time

    def gains(self, group_ops: Sequence[OpInfo], combos: Sequence) -> np.ndarray:
        t0 = self.base_time()
        out = []
        for combo in combos:
            if all(f == "bf16" for f in combo):
                out.append(0.0)
                continue
            assignment = {op.name: f for op, f in zip(group_ops, combo)}
            out.append(t0 - self._time(assignment))
        return np.array(out)
