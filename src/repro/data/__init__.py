from repro.data.synthetic import SyntheticConfig, SyntheticLM

__all__ = ["SyntheticConfig", "SyntheticLM"]
