"""Deterministic synthetic LM data.

Stream properties:
* **step-seeded**: ``batch_at(step)`` derives every batch from
  ``fold_in(root_key, step)`` — a restarted job regenerates the identical
  stream with zero iterator state to checkpoint (the fault-tolerance story
  for the data pipeline), and any host can materialize its own shard.
* **learnable structure** so a few hundred steps show a real loss drop:
  Zipf-distributed unigrams + Markov bigram chains + induction segments
  (a random motif repeated later in the sequence) — a small transformer
  quickly learns the bigram + copy structure.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["SyntheticConfig", "SyntheticLM"]


@dataclasses.dataclass(frozen=True)
class SyntheticConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    motif_len: int = 16
    zipf_a: float = 1.2
    n_bigram_states: int = 64


class SyntheticLM:
    def __init__(self, cfg: SyntheticConfig):
        self.cfg = cfg
        self.root = jax.random.key(cfg.seed)
        V = cfg.vocab_size
        # fixed random bigram table: state -> preferred successors
        k1, k2 = jax.random.split(jax.random.key(cfg.seed + 1))
        self.bigram_next = jax.random.randint(
            k1, (min(cfg.n_bigram_states, V),), 0, V)
        # Zipf weights over the vocab
        ranks = jnp.arange(1, V + 1, dtype=jnp.float32)
        self.zipf_logits = -cfg.zipf_a * jnp.log(ranks)

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        key = jax.random.fold_in(self.root, step)
        k_tok, k_pos, k_motif, k_mix = jax.random.split(key, 4)
        B, T, V = cfg.batch, cfg.seq_len + 1, cfg.vocab_size

        toks = jax.random.categorical(
            k_tok, jnp.broadcast_to(self.zipf_logits, (B, T, V)))

        # bigram chains: with p=0.5, next token = table[prev % states]
        def chain(carry, x):
            prev = carry
            tok, gate = x
            nxt = jnp.where(gate,
                            self.bigram_next[prev % self.bigram_next.shape[0]],
                            tok)
            return nxt, nxt
        gates = jax.random.bernoulli(k_mix, 0.5, (B, T))
        _, toks = jax.lax.scan(
            chain, toks[:, 0], (toks.swapaxes(0, 1), gates.swapaxes(0, 1)))
        toks = toks.swapaxes(0, 1)

        # induction motif: copy a motif to a later position in each row
        M = min(cfg.motif_len, T // 4)
        src = jax.random.randint(k_pos, (B,), 0, T // 2 - M)
        dst = jax.random.randint(k_motif, (B,), T // 2, T - M)
        idx = jnp.arange(T)[None, :]
        in_dst = (idx >= dst[:, None]) & (idx < (dst + M)[:, None])
        src_idx = jnp.clip(idx - dst[:, None] + src[:, None], 0, T - 1)
        motif = jnp.take_along_axis(toks, src_idx, axis=1)
        toks = jnp.where(in_dst, motif, toks).astype(jnp.int32)

        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batches(self, start_step: int, n: int):
        for s in range(start_step, start_step + n):
            yield self.batch_at(s)
