"""FP8 gradient compression with error feedback.

Large-scale trick: gradients cross the interconnect in fp8 (4x fewer bytes
than fp32 all-reduce) while an error-feedback buffer re-injects the
quantization residual into the next step, keeping the accumulated bias
negligible (1-bit-Adam / DALL-E-style EF). Two entry points:

* ``compress_decompress`` — value-level compress(+EF) for testing and for
  wrapping grads before the optimizer;
* ``compressed_psum`` — shard_map-ready collective: quantize -> psum in fp8
  payloads -> dequantize (used when the mesh axis is explicit).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant import qtensor
from repro.quant.formats import get_format

__all__ = ["compress_decompress", "compressed_psum", "compress_tree"]


def compress_decompress(g: jax.Array, err: jax.Array,
                        fmt_name: str = "fp8_e4m3") -> tuple:
    """Returns (g_compressed_roundtrip, new_err). g + err is quantized; the
    quantization residual becomes the next step's error feedback."""
    target = g + err
    q = qtensor.fake_quant(target.astype(jnp.float32), fmt_name)
    new_err = target - q
    return q.astype(g.dtype), new_err.astype(err.dtype)


def compress_tree(grads, err_tree, fmt_name: str = "fp8_e4m3"):
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    outs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        o, ne = compress_decompress(g, e, fmt_name)
        outs.append(o)
        errs.append(ne)
    return jax.tree.unflatten(tdef, outs), jax.tree.unflatten(tdef, errs)


def compressed_psum(x: jax.Array, axis_name: str,
                    fmt_name: str = "fp8_e4m3") -> jax.Array:
    """All-reduce with fp8 wire format (inside shard_map/pmap).

    The summand is quantized with a per-shard scale; the scales are maxed
    across the axis so every shard dequantizes consistently.
    """
    fmt = get_format(fmt_name)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    amax = jax.lax.pmax(amax, axis_name)
    scale = fmt.max_value / jnp.maximum(amax, 1e-12)
    xq = (x.astype(jnp.float32) * scale).astype(fmt.dtype)
    # fp8 payload summation happens in f32 accumulation on-wire equivalents;
    # XLA lowers psum on fp8 by upcast-accumulate (documented)
    s = jax.lax.psum(xq.astype(jnp.float32), axis_name)
    return (s / scale).astype(x.dtype)
