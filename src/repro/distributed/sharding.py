"""Logical-axis sharding rules (MaxText-style).

Every ParamSpec carries logical axis names; rules map them to (tuples of)
mesh axes. Assignment is *divisibility-checked*: if a dim is not divisible by
the mesh-axis product (e.g. hymba's 25 attention heads on a 16-way model
axis, whisper's 51865 vocab), the dim falls back to replication instead of
failing — robustness the multi-pod dry-run relies on. Each mesh axis is used
at most once per param.

DP  = batch over (pod, data)      TP = ffn/heads/vocab over model
EP  = experts over model          SP = sequence over model (opt-in, long ctx)
ZeRO-1 = optimizer state additionally sharded over data (largest free dim).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn.spec import ParamSpec

__all__ = ["DEFAULT_RULES", "partition_spec", "param_shardings",
           "zero_partition_spec", "batch_pspec", "named",
           "ServingMeshLayout", "serving_layout_scope",
           "current_serving_layout"]

# logical axis -> candidate mesh axes (tuple = shard jointly over all)
DEFAULT_RULES = {
    "vocab": ("model",),
    "ffn": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": ("model",),   # fallback when kv_heads % model != 0
    "kv_seq": ("model",),     # MLA latent cache: sequence-sharded
    "kv_lora": ("model",),
    "experts": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "embed": (),            # replicated (activations are batch-sharded)
    "layers": (),           # stacked-layer leading dim: never sharded
    "act_batch": ("pod", "data"),
    "kv_blocks": ("data",),   # paged-KV pool pages: device-sharded pool
    None: (),
}

# FSDP / ZeRO-3: additionally shard the replicated 'embed' dim of every
# weight over 'data' (and 'pod' when present: /512 at two pods); XLA
# all-gathers at use. Enabled when TP-only parameter shards exceed the HBM
# comfort budget.
FSDP_RULES = dict(DEFAULT_RULES, embed=("data", "pod"))


def _axes_in_mesh(mesh: Mesh, axes: tuple) -> tuple:
    return tuple(a for a in axes if a in mesh.axis_names)


def _mesh_size(mesh: Mesh, axes: tuple) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def partition_spec(spec: ParamSpec, mesh: Mesh,
                   rules: Optional[dict] = None) -> P:
    rules = rules or DEFAULT_RULES
    parts = []
    used: set = set()
    for dim, ax in zip(spec.shape, spec.logical_axes):
        cands = _axes_in_mesh(mesh, rules.get(ax, ()))
        cands = tuple(a for a in cands if a not in used)
        assigned = None
        # try the full tuple first, then progressively shorter prefixes
        for k in range(len(cands), 0, -1):
            sub = cands[:k]
            if dim % _mesh_size(mesh, sub) == 0:
                assigned = sub if len(sub) > 1 else sub[0]
                used.update(sub)
                break
        parts.append(assigned)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def zero_partition_spec(spec: ParamSpec, mesh: Mesh,
                        rules: Optional[dict] = None) -> P:
    """Param pspec + ZeRO-1: shard one replicated dim over 'data' if possible."""
    base = partition_spec(spec, mesh, rules)
    parts = list(base) + [None] * (len(spec.shape) - len(base))
    if "data" not in mesh.axis_names:
        return base
    flat_used = set()
    for p in parts:
        for a in (p if isinstance(p, tuple) else (p,)):
            if a:
                flat_used.add(a)
    if "data" in flat_used:
        return base
    # choose the largest divisible unassigned dim (skip stacked 'layers' dim 0
    # only if unsized); prefer later dims (contiguous shards)
    best = None
    for i, (dim, p) in enumerate(zip(spec.shape, parts)):
        if p is None and dim % mesh.shape["data"] == 0 and dim > 1:
            if best is None or dim >= spec.shape[best]:
                best = i
    if best is not None:
        parts[best] = "data"
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(specs: dict, mesh: Mesh, rules: Optional[dict] = None,
                    zero: bool = False) -> dict:
    fn = zero_partition_spec if zero else partition_spec
    return {path: NamedSharding(mesh, fn(s, mesh, rules))
            for path, s in specs.items()}


def shard_hint(x, *spec) -> jax.Array:
    """Best-effort ``with_sharding_constraint``: no-op outside a mesh context
    or when the named axes don't exist. Lets mesh-agnostic model code pin
    activation shardings (e.g. the per-head dim of MLA's expanded K/V).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty:
            from jax._src.mesh import thread_resources
            mesh = thread_resources.env.physical_mesh  # `with mesh:` context
            if mesh is None or mesh.empty:
                return x
        axes = set(mesh.axis_names)
        parts = []
        for p in spec:
            cands = tuple(a for a in (p if isinstance(p, tuple) else (p,))
                          if a in axes)
            parts.append(cands if len(cands) > 1 else
                         (cands[0] if cands else None))
        return jax.lax.with_sharding_constraint(x, P(*parts))
    except Exception:
        return x


def batch_pspec(mesh: Mesh, extra_dims: int = 1) -> P:
    """Batch-dim sharding over (pod, data); remaining dims replicated."""
    dp = _axes_in_mesh(mesh, ("pod", "data"))
    lead = dp if len(dp) > 1 else (dp[0] if dp else None)
    return P(lead, *([None] * extra_dims))


def named(mesh: Mesh, pspec: P) -> NamedSharding:
    return NamedSharding(mesh, pspec)


# ---------------------------------------------------------------------------
# Serving mesh layout
# ---------------------------------------------------------------------------
# The serving stack compiles its steps once per (model, kind, mp, ...) and
# the paged-attention dispatch inside the model needs to know, *at trace
# time*, how the serving state is laid out across the mesh: whether KV pages
# are device-sharded (so block ids must be translated to shard-local ids
# under shard_map) and whether batch/head extents divide the mesh axes. A
# contextvar carries that layout; `get_serving_step` activates it around each
# compiled step so retraces always see the layout they were memoised under.

@dataclasses.dataclass(frozen=True)
class ServingMeshLayout:
    """Static description of how serving state is spread over a mesh.

    ``shard_pages`` is True when the paged KV pool's leading block dim is
    sharded over ``data`` (requires ``n_blocks % data == 0``); each shard then
    owns ``blocks_per_shard`` consecutive pages and keeps its own trash block
    at local id 0. Slots always shard over ``data`` (``n_slots % data == 0``
    is asserted at construction).
    """
    mesh: Mesh
    data: int
    model: int
    n_slots: int
    block_size: int = 0
    n_blocks: int = 0
    shard_pages: bool = False
    blocks_per_shard: int = 0

    def fused_ok(self, batch: int, n_kv_heads: int) -> bool:
        """Can the fused paged kernel run per-shard under shard_map?"""
        return batch % self.data == 0 and n_kv_heads % self.model == 0


_SERVING_LAYOUT: contextvars.ContextVar = contextvars.ContextVar(
    "serving_mesh_layout", default=None)


@contextlib.contextmanager
def serving_layout_scope(layout: Optional[ServingMeshLayout]):
    token = _SERVING_LAYOUT.set(layout)
    try:
        yield layout
    finally:
        _SERVING_LAYOUT.reset(token)


def current_serving_layout() -> Optional[ServingMeshLayout]:
    return _SERVING_LAYOUT.get()
