from repro.hw.profiles import PROFILES, TPU_V5E, HWProfile, get_profile

__all__ = ["PROFILES", "TPU_V5E", "HWProfile", "get_profile"]
