"""Hardware profiles used by the theoretical/roofline performance metrics.

Peak numbers per chip. ``peak_flops`` maps format name -> FLOP/s achievable
when *both* GEMM operands are in that format. TPU v5e has no native fp8 MXU
mode; we model fp8 GEMMs at the int8 MXU rate (2x bf16), the same ratio
Gaudi-2's MME provides and what v6e delivers natively — the assumption is
recorded in DESIGN.md. fp4 is modeled at the fp8 rate on v5e (storage-only
benefit) and 2x fp8 on hardware with native support.
"""
from __future__ import annotations

import dataclasses

__all__ = ["HWProfile", "PROFILES", "get_profile", "TPU_V5E"]


@dataclasses.dataclass(frozen=True)
class HWProfile:
    name: str
    peak_flops: dict          # fmt name -> FLOP/s per chip
    hbm_bw: float             # bytes/s per chip
    ici_bw: float             # bytes/s per ICI link
    ici_links: int
    hbm_bytes: float
    vmem_bytes: float

    def flops(self, fmt: str) -> float:
        return self.peak_flops.get(fmt, self.peak_flops["bf16"])

    def mac_time(self, fmt: str) -> float:
        """Seconds per MAC (2 flops) in format ``fmt``."""
        return 2.0 / self.flops(fmt)

    def delta_T(self, fmt: str, ref: str = "bf16") -> float:
        """Per-MAC time gain of fmt vs the reference (paper Sec. 2.3.2)."""
        return self.mac_time(ref) - self.mac_time(fmt)


TPU_V5E = HWProfile(
    name="tpu_v5e",
    peak_flops={
        "bf16": 197e12,
        "fp16": 197e12,
        "fp8_e4m3": 394e12,
        "fp8_e5m2": 394e12,
        "fp4_e2m1": 394e12,
    },
    hbm_bw=819e9,
    ici_bw=50e9,
    ici_links=4,
    hbm_bytes=16e9,
    vmem_bytes=128e6,
)

TPU_V6E = HWProfile(
    name="tpu_v6e",
    peak_flops={
        "bf16": 918e12,
        "fp16": 918e12,
        "fp8_e4m3": 1836e12,
        "fp8_e5m2": 1836e12,
        "fp4_e2m1": 3672e12,
    },
    hbm_bw=1640e9,
    ici_bw=100e9,
    ici_links=4,
    hbm_bytes=32e9,
    vmem_bytes=128e6,
)

# The paper's platform, for cross-checking its reported ratios.
GAUDI2 = HWProfile(
    name="gaudi2",
    peak_flops={
        "bf16": 432e12,
        "fp16": 432e12,
        "fp8_e4m3": 865e12,
        "fp8_e5m2": 865e12,
        "fp4_e2m1": 865e12,
    },
    hbm_bw=2450e9,
    ici_bw=37.5e9,
    ici_links=24,
    hbm_bytes=96e9,
    vmem_bytes=48e6,
)

PROFILES = {p.name: p for p in (TPU_V5E, TPU_V6E, GAUDI2)}


def get_profile(name: str) -> HWProfile:
    return PROFILES[name]
