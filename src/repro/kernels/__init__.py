from repro.kernels import ops, ref
from repro.kernels.fp8_matmul import fp8_matmul
from repro.kernels.mp_attention import mp_flash_attention
from repro.kernels.paged_attention import paged_decode_attention
from repro.kernels.quant_cast import amax, quantize_fp8, scale_cast

__all__ = ["ops", "ref", "fp8_matmul", "mp_flash_attention",
           "paged_decode_attention", "amax", "quantize_fp8", "scale_cast"]
