"""Pallas-TPU API compatibility across JAX versions.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in newer
JAX releases; resolve whichever this install provides so the kernels build
against both.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # pragma: no cover - depends on installed jax version
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
