"""Scaled FP8 GEMM Pallas TPU kernel.

The execution primitive of the paper's MP configurations: a linear layer
whose operands are stored/consumed in FP8 with per-tensor scales and fp32
MXU accumulation, dequantized in the epilogue::

    Y = (Xq * sx_inv) @ (Wq * sw_inv)^T
      = (Xq @ Wq^T) * (sx_inv * sw_inv)      # scales fold into the epilogue

Tiling: (bm x bk) x (bn x bk) -> (bm x bn) blocks, K innermost ("arbitrary")
so partial products accumulate in a VMEM fp32 scratch; M/N grid dims are
parallel. Block shapes must be MXU-aligned (multiples of 128 on the matmul
dims; 32 on the fp8 lane dim is allowed but 128 keeps layouts trivial).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["fp8_matmul"]


def _kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[...].astype(jnp.float32)          # (bn, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        scale = sx_ref[0, 0] * sw_ref[0, 0]
        o_ref[...] = (acc_ref[...] * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def fp8_matmul(xq: jax.Array, wq: jax.Array, sx_inv: jax.Array,
               sw_inv: jax.Array, *, block_m: int = 256, block_n: int = 256,
               block_k: int = 512, out_dtype=jnp.bfloat16,
               interpret: bool = False) -> jax.Array:
    """xq: (M, K) fp8; wq: (N, K) fp8; scales: scalars. Returns (M, N)."""
    M, K = xq.shape
    N, K2 = wq.shape
    assert K == K2
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (
        f"pad shapes to block multiples: {(M, N, K)} vs {(bm, bn, bk)}")
    grid = (M // bm, N // bn, K // bk)

    sx = jnp.asarray(sx_inv, jnp.float32).reshape(1, 1)
    sw = jnp.asarray(sw_inv, jnp.float32).reshape(1, 1)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, wq, sx, sw)
