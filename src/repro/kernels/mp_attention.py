"""Mixed-precision flash attention Pallas TPU kernel.

The paper quantizes the two attention BGEMMs (``qk_matmul``, ``av_matmul``).
On TPU these never exist as standalone GEMMs — they live inside a fused
flash-attention kernel — so the TPU-native adaptation is a flash kernel
whose QK^T consumes (optionally) FP8 Q/K with per-tensor scales and whose
PV product consumes FP8 V (probabilities are quantized on the fly in-kernel,
matching eq. (15)'s noise model for the av_matmul lhs).

Grid (B, H, nq, nk), kv innermost; online-softmax running max/denominator
in VMEM scratch; causal blocks that are fully masked are skipped via
``pl.when`` (the block-level advantage the pure-JAX path lacks).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["mp_flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, sq_ref, sk_ref, sv_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
            block_q: int, block_k: int, n_k: int, quant_probs: bool):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * block_k <= (i + 1) * block_q - 1) if causal else True

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * sq_ref[0, 0]   # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32) * sk_ref[0, 0]   # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qi = i * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 0)
            ki = j * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        (block_q, block_k), 1)
            s = jnp.where(ki <= qi, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, -1, keepdims=True)
        if quant_probs:  # eq. (15) noise on the av_matmul lhs
            p = p.astype(jnp.float8_e4m3fn).astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32) * sv_ref[0, 0]   # (bk, Dv)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "block_q", "block_k", "quant_probs", "out_dtype", "interpret"))
def mp_flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                       sq: jax.Array = 1.0, sk: jax.Array = 1.0,
                       sv: jax.Array = 1.0, *, causal: bool = True,
                       block_q: int = 256, block_k: int = 256,
                       quant_probs: bool = False, out_dtype=jnp.bfloat16,
                       interpret: bool = False) -> jax.Array:
    """q,k,v: (B, H, T, D) (any float dtype incl. fp8); scales are the
    dequant multipliers (scale_inv). Returns (B, H, T, Dv) in out_dtype."""
    B, H, T, D = q.shape
    S = k.shape[2]
    Dv = v.shape[3]
    bq, bk = min(block_q, T), min(block_k, S)
    assert T % bq == 0 and S % bk == 0
    grid = (B, H, T // bq, S // bk)
    scalars = [jnp.asarray(s, jnp.float32).reshape(1, 1) for s in (sq, sk, sv)]

    return pl.pallas_call(
        functools.partial(_kernel, scale=1.0 / math.sqrt(D), causal=causal,
                          block_q=bq, block_k=bk, n_k=grid[3],
                          quant_probs=quant_probs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda b, h, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dv), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, *scalars)
