"""Jit'd high-level wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU so the same call sites work in this
CPU container (kernel bodies execute in Python) and compile to Mosaic on a
real TPU. Shapes are padded to block multiples here, never inside kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fp8_matmul as _mm
from repro.kernels import mp_attention as _attn
from repro.kernels import quant_cast as _qc
from repro.quant.formats import get_format

__all__ = ["default_interpret", "fp8_linear", "quantize_fp8",
           "flash_attention_mp"]


@functools.cache
def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mults: tuple) -> jax.Array:
    pads = []
    for dim, m in zip(x.shape, mults):
        pads.append((0, (-dim) % m))
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def fp8_linear(x: jax.Array, w: jax.Array, *, spec: str = "",
               fmt_name: str = "fp8_e4m3", out_dtype=jnp.bfloat16,
               block: int = 128, interpret=None) -> jax.Array:
    """y = x @ w^T with both operands quantized to fp8 (per-tensor scales).

    x: (M, C); w: (K, C). Fused quantize (amax kernel) + fp8 GEMM kernel.
    """
    interpret = default_interpret() if interpret is None else interpret
    fmt = get_format(fmt_name)
    dt = fmt.dtype or jnp.float8_e4m3fn
    M, C = x.shape
    K = w.shape[0]
    xp = _pad_to(x, (block, block))
    wp = _pad_to(w, (block, block))
    xq, sx_inv = _qc.quantize_fp8(xp, fmt.max_value, dt, interpret=interpret)
    wq, sw_inv = _qc.quantize_fp8(wp, fmt.max_value, dt, interpret=interpret)
    y = _mm.fp8_matmul(xq, wq, sx_inv, sw_inv, block_m=block, block_n=block,
                       block_k=max(block, 128), out_dtype=out_dtype,
                       interpret=interpret)
    return y[:M, :K]


def quantize_fp8(x: jax.Array, fmt_name: str = "fp8_e4m3", interpret=None):
    interpret = default_interpret() if interpret is None else interpret
    fmt = get_format(fmt_name)
    return _qc.quantize_fp8(x, fmt.max_value, fmt.dtype, interpret=interpret)


def flash_attention_mp(q, k, v, *, causal=True, fmt_name=None,
                       quant_probs=None, block=256, interpret=None):
    """(B,H,T,D) attention; fmt_name=None -> bf16, else quantize q/k/v."""
    interpret = default_interpret() if interpret is None else interpret
    sq = sk = sv = 1.0
    if fmt_name is not None:
        fmt = get_format(fmt_name)
        B, H, T, D = q.shape
        qq, sqv = _qc.quantize_fp8(q.reshape(-1, D), fmt.max_value, fmt.dtype,
                                   interpret=interpret)
        kq, skv = _qc.quantize_fp8(k.reshape(-1, D), fmt.max_value, fmt.dtype,
                                   interpret=interpret)
        vq, svv = _qc.quantize_fp8(v.reshape(-1, v.shape[-1]), fmt.max_value,
                                   fmt.dtype, interpret=interpret)
        q = qq.reshape(q.shape)
        k = kq.reshape(k.shape)
        v = vq.reshape(v.shape)
        sq, sk, sv = sqv, skv, svv
        if quant_probs is None:
            quant_probs = True
    return _attn.mp_flash_attention(q, k, v, sq, sk, sv, causal=causal,
                                    block_q=block, block_k=block,
                                    quant_probs=bool(quant_probs),
                                    interpret=interpret)
