"""Fused paged-attention decode Pallas TPU kernel.

Single-query (decode-step) attention computed *directly against the paged
KV store*: block-table indirection is resolved inside the kernel grid via
scalar prefetch — each grid step's BlockSpec index map reads the row's block
table and DMAs exactly one physical KV block — so per-step HBM traffic
scales with each row's *live* tokens instead of the provisioned
``max_blocks * block_size`` capacity that ``nn.layers.paged_gather``
materializes per layer. fp8 KV caches are dequantized in-register (never
written wide to HBM), which is what preserves the fp8-cache bandwidth win
at the decode step.

Layout contract (mirrors ``nn/layers.py`` paged caches):

* ``q``: (B, Hkv, G, Dk) — one query token per row, GQA via head-group
  reshape (H = Hkv * G). MLA absorbed decode passes Hkv=1, G=H.
* ``k``/``v``: (n_blocks, block_size, Hkv, D) block-major physical storage.
  ``v=None`` reuses ``k`` as values (MLA: both scores and context contract
  the latent ``ckv``). ``q2``/``k2`` optionally add a second score operand
  (MLA RoPE part): ``s = q @ k^T + q2 @ k2^T``.
* ``block_tables``: (B, max_blocks) int32, -1 = unallocated. Dead pages are
  clamped to the trash block 0 *in the index map*, so consecutive dead pages
  revisit the same block and the pipeline elides their copies — a row costs
  ~(live pages + 1) block fetches, not ``max_blocks``.
* ``lengths``: (B,) int32 live-token count (query position + 1). Keys at
  logical positions >= ``lengths[b]`` — stale or trash block contents — are
  masked before the softmax; with ``window`` set, positions at or below
  ``lengths[b] - 1 - window`` are masked too, and pages entirely outside
  the window are skipped like dead pages.

Numerics: two grid phases per row — phase 0 computes masked scores into a
VMEM scratch (tracking the running row max), phase 1 normalizes against the
*final* max/denominator and accumulates probs @ V. Unlike one-pass
flash-style rescaling, the probabilities here are bit-identical to the
materialized-softmax reference (``_reference_attention`` /
``_mla_decode_absorbed``) before the optional ``probs_dtype`` cast, so
greedy decode tokens match the ``paged_gather`` path. ``score_dtype`` /
``probs_dtype`` reproduce the reference's intermediate casts (bf16 for GQA
attention; None = keep f32, the MLA absorbed path). Each operand fetches a
live block only in the phase that consumes it (K in phase 0, V in phase 1
— both once per live block); the MLA ``v=None`` path reads its ``ckv``
blocks in both phases because keys and values share that storage.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

__all__ = ["paged_decode_attention", "BIG_WINDOW"]

# matches the reference path's mask fill (jnp.finfo(f32).min, not -inf: a
# fully-masked row then softmaxes to uniform garbage instead of NaN)
NEG = float(jnp.finfo(jnp.float32).min)
BIG_WINDOW = 1 << 30              # "no window" sentinel (fits int32)


def _kernel(bt_ref, len_ref, win_ref, q_ref, *rest, bs: int, n_pages: int,
            scale: float, scale_mode: str, score_dtype, probs_dtype,
            k_scale: float, v_scale: float, has_k2: bool, v_from_k: bool):
    refs = list(rest)
    k_ref = refs.pop(0)
    q2_ref = k2_ref = None
    if has_k2:
        q2_ref = refs.pop(0)
        k2_ref = refs.pop(0)
    v_ref = k_ref if v_from_k else refs.pop(0)
    o_ref, m_ref, l_ref, s_ref, acc_ref = refs

    b = pl.program_id(0)
    ph, j = pl.program_id(2), pl.program_id(3)
    ln = len_ref[b]
    win = win_ref[0]
    start = j * bs
    # any key of this page both causally live and inside the window?
    page_live = (start < ln) & (start + bs > ln - win)

    @pl.when((ph == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        s_ref[...] = jnp.full_like(s_ref, NEG)  # dead pages stay masked

    @pl.when((ph == 0) & page_live)
    def _scores():
        q = q_ref[0, 0]                                   # (G, Dk)
        k = _dequant(k_ref[0, :, 0, :], q.dtype, k_scale)  # (bs, Dk)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if has_k2:
            q2 = q2_ref[0, 0]
            k2 = _dequant(k2_ref[0, :, 0, :], q2.dtype, k_scale)
            s = s + jax.lax.dot_general(q2, k2, (((1,), (1,)), ((), ())),
                                        preferred_element_type=jnp.float32)
        if score_dtype is not None:   # reference rounds scores (bf16 GQA)
            s = s.astype(score_dtype)
        s = s.astype(jnp.float32)
        s = s / scale if scale_mode == "div" else s * scale
        kpos = start + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        live = (kpos < ln) & (kpos > ln - 1 - win)
        s = jnp.where(live, s, NEG)
        s_ref[:, pl.ds(start, bs)] = s
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(s, -1, keepdims=True))

    @pl.when((ph == 1) & (j == 0))
    def _denominator():
        l_ref[...] = jnp.sum(jnp.exp(s_ref[...] - m_ref[...]), -1,
                             keepdims=True)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when((ph == 1) & page_live)
    def _context():
        p = jnp.exp(s_ref[:, pl.ds(start, bs)] - m_ref[...]) / l_ref[...]
        if probs_dtype is not None:   # reference rounds probs (bf16 GQA)
            p = p.astype(probs_dtype)
        v = _dequant(v_ref[0, :, 0, :], p.dtype, v_scale)  # (bs, Dv)
        acc_ref[...] += jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when((ph == 1) & (j == n_pages - 1))
    def _out():
        o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)


def _dequant(x: jax.Array, dtype, scale: float) -> jax.Array:
    """In-register dequant of a (possibly fp8) KV block. ``scale`` is the
    per-tensor dequant multiplier (scale_inv); 1.0 skips the multiply so the
    unscaled path stays bit-identical to ``paged_gather``'s plain upcast."""
    if scale == 1.0:
        return x.astype(dtype)
    return (x.astype(jnp.float32) * scale).astype(dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "scale_mode", "score_dtype",
                              "probs_dtype", "k_scale", "v_scale",
                              "out_dtype", "interpret"))
def _call(q, k, v, q2, k2, block_tables, lengths, window, *, scale,
          scale_mode, score_dtype, probs_dtype, k_scale, v_scale,
          out_dtype, interpret):
    B, Hkv, G, Dk = q.shape
    bs = k.shape[1]
    n_pages = block_tables.shape[1]
    v_from_k = v is None
    Dv = k.shape[-1] if v_from_k else v.shape[-1]
    has_k2 = k2 is not None

    def kv_map(keep0, keep1):
        # dead pages map to the trash block 0 (consecutive revisits elide
        # their DMA); phases that don't consume the operand also map to 0
        def index(b, h, ph, j, bt, ln, wn):
            live = ((j * bs < ln[b]) & (j * bs + bs > ln[b] - wn[0])
                    & ((ph == 0) & keep0 | (ph == 1) & keep1))
            return (jnp.where(live, jnp.maximum(bt[b, j], 0), 0), 0, h, 0)
        return index

    def q_map(b, h, ph, j, *_):
        return (b, h, 0, 0)

    in_specs = [pl.BlockSpec((1, 1, G, Dk), q_map),
                pl.BlockSpec((1, bs, 1, Dk), kv_map(True, v_from_k))]
    operands = [q, k]
    if has_k2:
        in_specs += [pl.BlockSpec((1, 1, G, q2.shape[-1]), q_map),
                     pl.BlockSpec((1, bs, 1, k2.shape[-1]),
                                  kv_map(True, False))]
        operands += [q2, k2]
    if not v_from_k:
        in_specs.append(pl.BlockSpec((1, bs, 1, Dv), kv_map(False, True)))
        operands.append(v)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, 2, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, Dv), q_map),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),            # running max
            pltpu.VMEM((G, 1), jnp.float32),            # denominator
            pltpu.VMEM((G, n_pages * bs), jnp.float32),  # masked scores
            pltpu.VMEM((G, Dv), jnp.float32),            # output accumulator
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs, n_pages=n_pages, scale=scale,
                          scale_mode=scale_mode, score_dtype=score_dtype,
                          probs_dtype=probs_dtype, k_scale=k_scale,
                          v_scale=v_scale, has_k2=has_k2, v_from_k=v_from_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Dv), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")),
        interpret=interpret,
    )(block_tables, lengths, window, *operands)


def paged_decode_attention(q: jax.Array, k: jax.Array, v: Optional[jax.Array],
                           block_tables: jax.Array, lengths: jax.Array, *,
                           window=None, q2: Optional[jax.Array] = None,
                           k2: Optional[jax.Array] = None,
                           scale: float, scale_mode: str = "div",
                           score_dtype=None, probs_dtype=None,
                           k_scale: float = 1.0, v_scale: float = 1.0,
                           out_dtype=None, interpret: Optional[bool] = None
                           ) -> jax.Array:
    """Single-query paged attention: (B, Hkv, G, Dv) in ``out_dtype``.

    ``window`` may be None (full causal), a python int, or a traced int32
    scalar (scan-mode per-layer windows); ``scale_mode`` selects
    ``s / scale`` (GQA reference) vs ``s * scale`` (MLA absorbed reference).
    Rows whose ``lengths`` entry is 0 produce zeros. ``interpret`` defaults
    to True off-TPU so the same call site runs in CPU CI and compiles to
    Mosaic on a real TPU. On TPU, fp8 caches want ``block_size`` >= the fp8
    min sublane tile (32); smaller blocks still compile via Mosaic padding
    but waste tile bandwidth.
    """
    if interpret is None:
        from repro.kernels.ops import default_interpret
        interpret = default_interpret()
    if window is None:
        window = BIG_WINDOW
    window = jnp.asarray(window, jnp.int32).reshape(1)
    lengths = jnp.asarray(lengths, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    out_dtype = q.dtype if out_dtype is None else out_dtype
    return _call(q, k, v, q2, k2, block_tables, lengths, window,
                 scale=float(scale), scale_mode=scale_mode,
                 score_dtype=score_dtype, probs_dtype=probs_dtype,
                 k_scale=float(k_scale), v_scale=float(v_scale),
                 out_dtype=out_dtype, interpret=interpret)
