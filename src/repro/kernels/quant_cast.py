"""Fused amax -> scale -> FP8-cast Pallas kernel pair.

Runtime activation quantization is the per-op overhead the MP configuration
pays on every quantized layer (the RooflineGainModel charges read(bf16) +
write(fp8) for it). Fusing the reduction and the cast keeps it at exactly
one read + one tiny write + one read + one fp8 write.

Two kernels because amax is a full reduction: (1) per-row-tile amax partials,
(2) scale+cast with the folded scalar. Both tile (bm x N) row blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["amax", "scale_cast", "quantize_fp8"]


def _amax_kernel(x_ref, o_ref):
    o_ref[0, 0] = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)))


def _cast_kernel(x_ref, s_ref, o_ref):
    o_ref[...] = (x_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def amax(x: jax.Array, *, block_m: int = 256, interpret: bool = False):
    """Per-tensor abs-max of a 2D array via tiled partial reduction."""
    M, N = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    grid = (M // bm,)
    partial = pl.pallas_call(
        _amax_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid[0], 1), jnp.float32),
        interpret=interpret,
    )(x)
    return jnp.max(partial)


@functools.partial(jax.jit, static_argnames=("dtype", "block_m", "interpret"))
def scale_cast(x: jax.Array, scale: jax.Array, *, dtype=jnp.float8_e4m3fn,
               block_m: int = 256, interpret: bool = False) -> jax.Array:
    M, N = x.shape
    bm = min(block_m, M)
    assert M % bm == 0
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _cast_kernel,
        grid=(M // bm,),
        in_specs=[pl.BlockSpec((bm, N), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bm, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), dtype),
        interpret=interpret,
    )(x, s)


def quantize_fp8(x: jax.Array, max_value: float = 448.0,
                 dtype=jnp.float8_e4m3fn, interpret: bool = False):
    """Returns (xq, scale_inv): the fused amax->scale->cast pipeline."""
    a = amax(x, interpret=interpret)
    scale = max_value / jnp.maximum(a, 1e-12)
    xq = scale_cast(x, scale, dtype=dtype, interpret=interpret)
    return xq, 1.0 / scale
