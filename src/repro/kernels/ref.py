"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["fp8_matmul_ref", "amax_ref", "scale_cast_ref",
           "mp_flash_attention_ref"]


def fp8_matmul_ref(xq: jax.Array, wq: jax.Array, sx_inv, sw_inv,
                   out_dtype=jnp.bfloat16) -> jax.Array:
    y = jnp.einsum("mk,nk->mn", xq.astype(jnp.float32), wq.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return (y * sx_inv * sw_inv).astype(out_dtype)


def amax_ref(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def scale_cast_ref(x: jax.Array, scale, dtype=jnp.float8_e4m3fn) -> jax.Array:
    return (x.astype(jnp.float32) * scale).astype(dtype)


def mp_flash_attention_ref(q, k, v, sq=1.0, sk=1.0, sv=1.0, *,
                           causal=True, quant_probs=False,
                           out_dtype=jnp.bfloat16):
    """Materialized-softmax oracle with identical quantization semantics."""
    B, H, T, D = q.shape
    S = k.shape[2]
    qf = q.astype(jnp.float32) * sq
    kf = k.astype(jnp.float32) * sk
    vf = v.astype(jnp.float32) * sv
    s = jnp.einsum("bhtd,bhsd->bhts", qf, kf) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T)
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    if quant_probs:
        p = p.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    o = jnp.einsum("bhts,bhsd->bhtd", p, vf) / jnp.maximum(l, 1e-30)
    return o.astype(out_dtype)
