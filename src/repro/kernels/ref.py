"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["fp8_matmul_ref", "amax_ref", "scale_cast_ref",
           "mp_flash_attention_ref", "paged_decode_attention_ref"]


def fp8_matmul_ref(xq: jax.Array, wq: jax.Array, sx_inv, sw_inv,
                   out_dtype=jnp.bfloat16) -> jax.Array:
    y = jnp.einsum("mk,nk->mn", xq.astype(jnp.float32), wq.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    return (y * sx_inv * sw_inv).astype(out_dtype)


def amax_ref(x: jax.Array) -> jax.Array:
    return jnp.max(jnp.abs(x.astype(jnp.float32)))


def scale_cast_ref(x: jax.Array, scale, dtype=jnp.float8_e4m3fn) -> jax.Array:
    return (x.astype(jnp.float32) * scale).astype(dtype)


def _paged_deq(cache, block_tables, dtype, scale):
    """Gather-to-logical-order dequant (the ``paged_gather`` semantics)."""
    bs = cache.shape[1]
    B, npg = block_tables.shape
    g = jnp.take(cache, jnp.maximum(block_tables, 0), axis=0)
    g = g.reshape(B, npg * bs, *cache.shape[2:])
    if scale != 1.0:
        return (g.astype(jnp.float32) * scale).astype(dtype)
    return g.astype(dtype)


def paged_decode_attention_ref(q, k, v, block_tables, lengths, *,
                               window=None, q2=None, k2=None, scale,
                               scale_mode="div", score_dtype=None,
                               probs_dtype=None, k_scale=1.0, v_scale=1.0,
                               out_dtype=None):
    """Gather-then-attend oracle with the exact reference-path numerics
    (``nn.layers._reference_attention`` / ``_mla_decode_absorbed``): gather
    each row's blocks into logical order, mask by length/window, softmax in
    f32 with the reference's intermediate casts. Shapes as in
    :func:`repro.kernels.paged_attention.paged_decode_attention`."""
    B, Hkv, G, Dk = q.shape
    out_dtype = q.dtype if out_dtype is None else out_dtype
    kg = _paged_deq(k, block_tables, q.dtype, k_scale)      # (B, S, Hkv, Dk)
    s = jnp.einsum("BKGD,BSKD->BKGS", q, kg,
                   preferred_element_type=jnp.float32)
    if q2 is not None:
        k2g = _paged_deq(k2, block_tables, q2.dtype, k_scale)
        s = s + jnp.einsum("BKGD,BSKD->BKGS", q2, k2g,
                           preferred_element_type=jnp.float32)
    if score_dtype is not None:
        s = s.astype(score_dtype)
    s = s.astype(jnp.float32)
    s = s / scale if scale_mode == "div" else s * scale
    S = kg.shape[1]
    kpos = jnp.arange(S, dtype=jnp.int32)[None, :]
    live = kpos < lengths[:, None]
    if window is not None:
        live &= kpos > (lengths[:, None] - 1 - window)
    s = jnp.where(live[:, None, None, :], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    if probs_dtype is not None:
        p = p.astype(probs_dtype)
    vsrc = k if v is None else v
    vg = _paged_deq(vsrc, block_tables, p.dtype, v_scale)
    o = jnp.einsum("BKGS,BSKD->BKGD", p, vg,
                   preferred_element_type=jnp.float32)
    # rows with length 0 attend nothing in the kernel; zero them here too
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0.0)
    return o.astype(out_dtype)


def mp_flash_attention_ref(q, k, v, sq=1.0, sk=1.0, sv=1.0, *,
                           causal=True, quant_probs=False,
                           out_dtype=jnp.bfloat16):
    """Materialized-softmax oracle with identical quantization semantics."""
    B, H, T, D = q.shape
    S = k.shape[2]
    qf = q.astype(jnp.float32) * sq
    kf = k.astype(jnp.float32) * sk
    vf = v.astype(jnp.float32) * sv
    s = jnp.einsum("bhtd,bhsd->bhts", qf, kf) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((T, S), bool), k=S - T)
        s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    if quant_probs:
        p = p.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    o = jnp.einsum("bhts,bhsd->bhtd", p, vf) / jnp.maximum(l, 1e-30)
    return o.astype(out_dtype)
