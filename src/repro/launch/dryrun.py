import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

# ---------------------------------------------------------------------------
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
# the production sharding and record memory/cost/roofline evidence.
# The two lines above MUST precede any jax-importing module (device count is
# locked at first backend init).
# ---------------------------------------------------------------------------
import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis import model_stats, roofline  # noqa: E402
from repro.configs.shapes import SHAPES, cell_supported, input_specs  # noqa: E402
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import steps  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.registry import ARCH_IDS, build_model, get_config  # noqa: E402
from repro.nn.spec import tree_from_flat  # noqa: E402
from repro.train import optim  # noqa: E402

ASSIGNED = [a for a in ARCH_IDS if a not in ("llama3_1b", "llama3_8b")]

# dry-run model options per cell kind (see DESIGN.md: scan segments keep the
# 61-layer HLO O(1); remat bounds train activation memory)
DRYRUN_OVERRIDES = dict(scan_layers=True, remat=True)

# per-arch gradient-accumulation depth for train_4k (memory-fit driven;
# see EXPERIMENTS.md section Dry-run)
DRYRUN_MICRO = {"starcoder2_15b": 8, "deepseek_v3_671b": 8}


def _build(arch: str, kind: str, overrides: dict):
    ov = dict(overrides)
    if arch == "whisper_base":
        ov = {k: v for k, v in ov.items() if k in ("flash_min_seq",)}
        cfg = get_config(arch, **ov)
    else:
        if kind != "train":
            ov["remat"] = False
        cfg = get_config(arch, **ov)
    return build_model(cfg)


def _abstract(specs: dict, shardings: dict) -> dict:
    flat = {p: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=shardings[p])
            for p, s in specs.items()}
    return tree_from_flat(flat)


def _shard_inputs(mesh, ins: dict) -> dict:
    out = {}
    for k, v in ins.items():
        if v.shape and v.shape[0] > 1:
            ps = shd.batch_pspec(mesh, extra_dims=len(v.shape) - 1)
            # divisibility fallback
            dp = ps[0]
            size = 1
            for a in (dp if isinstance(dp, tuple) else (dp,)):
                if a:
                    size *= mesh.shape[a]
            if v.shape[0] % size != 0:
                ps = P(*([None] * len(v.shape)))
        else:
            ps = P(*([None] * len(v.shape)))
        out[k] = jax.ShapeDtypeStruct(v.shape, v.dtype,
                                      sharding=NamedSharding(mesh, ps))
    return out


def _cache_abstract(model, mesh, cell, rules=None):
    from repro.models.encdec import EncDec
    if isinstance(model, EncDec):
        specs = model.cache_specs(cell.global_batch, cell.seq_len,
                                  enc_len=cell.seq_len)
        flat = {}
        for k, s in specs.items():
            ps = shd.partition_spec(s, mesh, rules)
            flat[k] = jax.ShapeDtypeStruct(s.shape, s.dtype,
                                           sharding=NamedSharding(mesh, ps))
        caches = {}
        for key, v in flat.items():
            layer, leaf = key.rsplit("/", 1)
            caches.setdefault(layer, {})[leaf] = v
        return caches
    specs = model.cache_specs(cell.global_batch, cell.seq_len)
    flat = {}
    for k, s in specs.items():
        ps = shd.partition_spec(s, mesh, rules)
        flat[k] = jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=NamedSharding(mesh, ps))
    tree = model._cache_tree(flat)
    out = {}
    for lk, subs in tree.items():
        if set(subs) == {"attn"}:
            out[lk] = subs["attn"]
        elif set(subs) == {"mamba"}:
            out[lk] = subs["mamba"]
        else:
            out[lk] = subs
    return out


# per-device parameter-shard budget above which FSDP (ZeRO-3) kicks in
FSDP_THRESHOLD_BYTES = 3e9
# decode cells of models whose bf16 KV cache would not leave room on v5e
KV_FP8_THRESHOLD_BYTES = 4e9


def _estimate_shard_bytes(specs: dict, shardings: dict, mesh) -> float:
    import math as _m
    total = 0.0
    for p, s in specs.items():
        n = _m.prod(s.shape) * jnp.dtype(s.dtype).itemsize
        ps = shardings[p].spec
        denom = 1
        for part in ps:
            for a in (part if isinstance(part, tuple) else (part,)):
                if a:
                    denom *= mesh.shape[a]
        total += n / denom
    return total


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None, mp_assignment=None) -> dict:
    cell = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "reason": ""}
    ok, reason = cell_supported(arch, shape_name)
    if not ok:
        rec.update(status="skip", reason=reason)
        return rec

    t0 = time.time()
    overrides = dict(overrides or {})
    n_micro = overrides.pop("n_microbatches", DRYRUN_MICRO.get(arch, 4))
    rules_override = overrides.pop("rules", None)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = _build(arch, cell.kind, dict(DRYRUN_OVERRIDES, **overrides))
    specs = model.param_specs()
    rules = rules_override or shd.DEFAULT_RULES
    p_sh = shd.param_shardings(specs, mesh, rules=rules)
    if rules_override is None and \
            _estimate_shard_bytes(specs, p_sh, mesh) > FSDP_THRESHOLD_BYTES:
        rules = shd.FSDP_RULES
        p_sh = shd.param_shardings(specs, mesh, rules=rules)
        rec["fsdp"] = True
    # fp8 KV cache when the bf16 cache would crowd out v5e HBM (decode cells)
    if cell.kind == "decode" and hasattr(model.cfg, "kv_cache_dtype") \
            and "kv_cache_dtype" not in overrides:
        c_specs = model.cache_specs(cell.global_batch, cell.seq_len)
        c_sh = {k: shd.named(mesh, shd.partition_spec(s, mesh, rules))
                for k, s in c_specs.items()}
        if _estimate_shard_bytes(c_specs, c_sh, mesh) > KV_FP8_THRESHOLD_BYTES:
            model = _build(arch, cell.kind,
                           dict(DRYRUN_OVERRIDES, **overrides,
                                kv_cache_dtype="fp8_e4m3"))
            rec["kv_cache_dtype"] = "fp8_e4m3"
    params_abs = _abstract(specs, p_sh)
    ins = _shard_inputs(mesh, input_specs(model, cell))

    with mesh:
        if cell.kind == "train":
            opt_cfg = optim.select_optimizer(model_stats.param_stats(model)["total"])
            s_specs = optim.state_specs(specs, opt_cfg)
            s_sh = shd.param_shardings(s_specs, mesh, rules=rules, zero=True)
            opt_abs = _abstract(s_specs, s_sh)
            step = steps.make_train_step(model, opt_cfg, mp=mp_assignment,
                                         n_microbatches=n_micro)
            rec["n_microbatches"] = n_micro
            out_sh = (jax.tree.map(lambda x: x.sharding, params_abs),
                      jax.tree.map(lambda x: x.sharding, opt_abs), None)
            fn = jax.jit(step, donate_argnums=(0, 1), out_shardings=out_sh)
            lowered = fn.lower(params_abs, opt_abs, ins)
            rec["optimizer"] = opt_cfg.name
        elif cell.kind == "prefill":
            caches = _cache_abstract(model, mesh, cell, rules)
            step = steps.make_prefill_step(model, mp=mp_assignment)
            out_sh = (None, jax.tree.map(lambda x: x.sharding, caches))
            fn = jax.jit(step, donate_argnums=(1,), out_shardings=out_sh)
            lowered = fn.lower(params_abs, caches, ins)
        else:
            caches = _cache_abstract(model, mesh, cell, rules)
            step = steps.make_decode_step(model, mp=mp_assignment)
            out_sh = (None, jax.tree.map(lambda x: x.sharding, caches))
            fn = jax.jit(step, donate_argnums=(1,), out_shardings=out_sh)
            pos = jax.ShapeDtypeStruct((), jnp.int32,
                                       sharding=NamedSharding(mesh, P()))
            lowered = fn.lower(params_abs, caches, ins["token"], pos)
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    mem_stats = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_stats[attr] = int(v)
    # live bytes per device (args are aliased/donated where possible)
    arg = mem_stats.get("argument_size_in_bytes", 0)
    tmp = mem_stats.get("temp_size_in_bytes", 0)
    out_b = mem_stats.get("output_size_in_bytes", 0)
    alias = mem_stats.get("alias_size_in_bytes", 0)
    mem_stats["peak_estimate_bytes"] = arg + tmp + max(out_b - alias, 0)
    rec["memory_analysis"] = mem_stats

    cost = compiled.cost_analysis() or {}
    cost_small = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and k in
                  ("flops", "bytes accessed", "optimal_seconds",
                   "utilization operand 0 {}", "bytes accessed output {}")}
    rec["cost_analysis"] = cost_small

    hlo = compiled.as_text()
    chips = mesh.devices.size
    mf = model_stats.model_flops(model, cell)
    rep = roofline.analyze(arch=arch, shape=shape_name, mesh_name=mesh_name,
                           chips=chips, cost=cost, hlo_text=hlo,
                           model_flops=mf, memory_stats=mem_stats)
    rec["roofline"] = rep.to_dict()
    coll = rec["roofline"]["meta"]["collectives"]
    rec["collective_split"] = {"toplevel": coll.get("toplevel", 0.0),
                               "inloop": coll.get("inloop", 0.0)}
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=("single", "multi", "both"))
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
                if os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("status") in ("ok", "skip"):
                            print(f"[cached] {path}")
                            continue
                print(f"[dryrun] {arch} x {shape} x {mesh_name} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp)
                except Exception as e:  # record, keep sweeping
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "reason": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                jax.clear_caches()
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t=({r['t_compute']:.3e},{r['t_memory']:.3e},"
                             f"{r['t_collective']:.3e})s"
                             f" mem/dev={rec['memory_analysis'].get('peak_estimate_bytes',0)/1e9:.2f}GB"
                             f" compile={rec.get('compile_s')}s")
                print(f"  -> {status}{extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
