"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no JAX device state. Single pod = 256 chips
(16x16 data x model); multi-pod adds a leading 2-way ``pod`` axis (512).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    assert len(devs) >= need, (
        f"need {need} devices (set XLA_FLAGS=--xla_force_host_platform_"
        f"device_count=512 before importing jax); have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / CPU runs)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:data * model])
