"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no JAX device state. Single pod = 256 chips
(16x16 data x model); multi-pod adds a leading 2-way ``pod`` axis (512).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "parse_mesh_spec",
           "mesh_from_spec"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 1
    for s in shape:
        need *= s
    devs = jax.devices()
    assert len(devs) >= need, (
        f"need {need} devices (set XLA_FLAGS=--xla_force_host_platform_"
        f"device_count=512 before importing jax); have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:need])


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over the locally available devices (tests / CPU runs)."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:data * model])


def parse_mesh_spec(spec: str) -> dict:
    """``"data=2,model=4"`` -> ``{"data": 2, "model": 4}``. The CLI surface
    for serving meshes (``--mesh``); unknown axes are rejected so a typo
    can't silently serve unsharded."""
    out = {"data": 1, "model": 1}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        try:
            axis, _, val = part.partition("=")
            n = int(val)
        except ValueError:
            raise ValueError(f"bad mesh spec part {part!r} in {spec!r} "
                             f"(expected axis=N)") from None
        if axis not in out:
            raise ValueError(f"unknown mesh axis {axis!r} in {spec!r} "
                             f"(serving meshes have data/model)")
        assert n >= 1, (axis, n)
        out[axis] = n
    return out


def mesh_from_spec(spec):
    """``--mesh`` string to a local serving mesh; None/empty/1x1 -> None
    (the single-device engine path, no mesh context anywhere)."""
    if not spec:
        return None
    axes = parse_mesh_spec(spec) if isinstance(spec, str) else dict(spec)
    if axes.get("data", 1) == 1 and axes.get("model", 1) == 1:
        return None
    return make_local_mesh(data=axes["data"], model=axes["model"])
