"""Serving launcher: one-shot batch or continuous-batching serving under an
optional MP plan — or an MP plan solved *at serve time* from a saved
calibration bundle.

    # one-shot (the paper's TTFT measurement harness)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_1b --smoke \
        --mp-plan plan.json --batch 4 --new-tokens 16

    # solve per serving SLA from a calibrate() artifact — no recalibration
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_1b --smoke \
        --calibration bundle.npz --tau 0.01 --objective ET

    # continuous batching: staggered arrivals drain through a paged KV pool
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_1b --smoke \
        --continuous --n-slots 4 --requests 12 --arrival-every 2 \
        --block-size 16 --n-blocks 24        # (--dense-slots for the old rings)

Loads params from a checkpoint directory if given, else random-init (smoke
demos). An ``--mp-plan`` json (saved by ``MPPlan.save``) flows straight into
either engine; ``--calibration`` loads a ``CalibrationBundle`` and runs the
cheap IP for the requested ``--tau`` / ``--objective`` right here. Reports
TTFT (the paper's measured quantity) and decode throughput.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.mpconfig import MPPlan
from repro.core.pipeline import CalibrationBundle
from repro.models.registry import get_model
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine


def _serving_op_names(model, params):
    """Abstract-trace the serving prefill; returns its op-name set, or None
    when the arch keeps a separate serving op namespace."""
    from repro.models.encdec import EncDec
    from repro.quant.qops import QuantContext
    if isinstance(model, EncDec):
        return None  # encoder-decoder serving keeps its own op namespace
    registry: list = []
    ctx = QuantContext(mode="plain", registry=registry)
    tokens = jax.ShapeDtypeStruct((1, 8), jnp.int32)
    caches = model.init_cache(1, 16, abstract=True)
    jax.eval_shape(lambda p, t, c: model.prefill(p, t, c, ctx),
                   params, tokens, caches)
    return {op.name for op in registry}


def _plan_unknown_ops(model, params, plan: MPPlan) -> set:
    """Flag plan ops this model lacks (plan solved for a different arch)."""
    known = _serving_op_names(model, params)
    return set() if known is None else plan.unknown_ops(known)


def _check_bundle_ops(model, params, bundle: CalibrationBundle,
                      src: str) -> None:
    """Validate the artifact against this model's op namespace."""
    known = _serving_op_names(model, params)
    if known is not None:
        unknown = bundle.unknown_ops(known)
        if unknown:
            raise SystemExit(
                f"[serve] calibration bundle ({src}) has {len(unknown)} ops "
                f"not in this model (e.g. {sorted(unknown)[:3]}); was it "
                f"calibrated for a different arch?")


def _solve_from_bundle(bundle: CalibrationBundle, args, src: str) -> MPPlan:
    """Serve-time solve: run the cheap IP for the requested SLA."""
    plan = bundle.solve(tau=args.tau, objective=args.objective)
    tier = plan.meta.get("gain_tier", "analytic")
    print(f"[serve] solved from {src}: tau {plan.tau} "
          f"objective {plan.objective} -> {plan.n_quantized} ops quantized "
          f"(predicted gain {plan.predicted_gain:.3e} [{tier}], "
          f"MSE {plan.predicted_loss_mse:.3e} <= {plan.budget:.3e})")
    if tier == "roofline_fallback":
        print("[serve] note: no measured wall-clock gain table in this "
              "bundle — the solve used roofline gains (run "
              "tabulate_measured_gains + re-save to upgrade)")
    return plan


def _registry_bundle(model, params, path: str):
    """Serve-time registry lookup: the freshest bundle compatible with the
    arch and the *actual* restored params' fingerprint."""
    from repro.core.pipeline import _params_fingerprint
    from repro.core.registry import BundleRegistry
    arch = getattr(model.cfg, "name", None)
    fp = _params_fingerprint(params)
    bundle = BundleRegistry(path).find(arch, fp)
    src = f"{path}:{arch}/{fp}"
    print(f"[serve] registry match: arch {arch} fingerprint {fp} "
          f"(calib_hash {bundle.meta.get('calib_hash')})")
    return bundle, src


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mp-plan", default=None, help="MPPlan json path")
    ap.add_argument("--calibration", default=None,
                    help="CalibrationBundle path (json/npz): solve the IP at "
                         "serve time instead of loading a fixed plan")
    ap.add_argument("--tau", type=float, default=None,
                    help="loss-MSE threshold for --calibration solves "
                         "(default: the bundle's calibration-time tau)")
    ap.add_argument("--objective", default=None, choices=("ET", "TT", "M"),
                    help="IP objective for --calibration solves")
    ap.add_argument("--registry", default=None,
                    help="bundle registry root: pick the freshest "
                         "calibration bundle compatible with this arch and "
                         "the restored checkpoint's fingerprint, instead of "
                         "trusting a hand-passed --calibration path")
    ap.add_argument("--adaptive-tau", type=float, default=None,
                    help="enable load-adaptive MP (continuous mode; needs "
                         "--calibration or --registry): serve under a tau "
                         "ladder starting at this base, escalating to more "
                         "aggressive plans as the queue grows and restoring "
                         "as it drains")
    ap.add_argument("--adaptive-levels", type=int, default=3,
                    help="tau ladder depth (base * factor**i)")
    ap.add_argument("--adaptive-factor", type=float, default=2.0)
    ap.add_argument("--adaptive-every", type=int, default=2,
                    help="controller evaluation cadence in engine ticks")
    ap.add_argument("--adaptive-dwell", type=int, default=4,
                    help="min ticks between plan swaps")
    ap.add_argument("--adaptive-queue-high", type=int, default=2,
                    help="queue-depth watermark that triggers escalation")
    ap.add_argument("--adaptive-queue-low", type=int, default=0,
                    help="queue-depth watermark below which to restore")
    ap.add_argument("--inject-faults", default=None,
                    help="deterministic fault schedule for the continuous "
                         "engine, e.g. 'nan_page@3,alloc_fail@5:slot=1' "
                         "(kind@step[:k=v,...]); see repro.serve.FaultSpec")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="bounded per-request retries after a contained "
                         "fault before the request is marked failed")
    ap.add_argument("--guardrail-every", type=int, default=None,
                    help="enable the tau-anchored numerical guardrail: run a "
                         "high-precision shadow step every N decode steps and "
                         "compare logit MSE against the active plan's "
                         "loss-MSE budget (continuous mode with an MP plan)")
    ap.add_argument("--guardrail-margin", type=float, default=4.0,
                    help="breach when shadow MSE > margin * budget")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a staggered request stream instead of one batch")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="decode steps between request arrivals")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged KV block size in tokens (continuous mode)")
    ap.add_argument("--n-blocks", default=None,
                    help="paged KV pool size incl. the trash block(s): an "
                         "int, 'auto' to size from the request profile "
                         "(p95 live-block demand x headroom, see "
                         "PagedCachePool.size_n_blocks), or omit for the "
                         "worst case (never backpressures)")
    ap.add_argument("--mesh", default=None,
                    help="serving mesh spec like 'data=2,model=2' "
                         "(continuous mode; needs data*model JAX devices, "
                         "e.g. XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N on CPU); omit for single-device")
    ap.add_argument("--chunk-len", type=int, default=None,
                    help="split prompts into prefill chunks of this many "
                         "tokens, interleaved with decode steps (continuous "
                         "paged mode; prompts are length-bucketed either way)")
    ap.add_argument("--chunk-budget", type=int, default=1,
                    help="max prefill chunk steps between decode steps "
                         "(bounds per-request decode stall while a long "
                         "prompt prefills)")
    ap.add_argument("--dense-slots", action="store_true",
                    help="use monolithic per-slot rings instead of paged "
                         "KV blocks (continuous mode)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request prefix sharing (paged "
                         "continuous engines content-hash admitted prompts "
                         "against resident blocks by default and skip "
                         "prefill for matched full blocks)")
    ap.add_argument("--paged-attn", default=None,
                    choices=("fused", "gather"),
                    help="paged decode attention: 'fused' (default) attends "
                         "block-major KV in place via the Pallas kernel; "
                         "'gather' keeps the reference path that "
                         "materializes logical (B, S) K/V per layer")
    ap.add_argument("--sync-engine", action="store_true",
                    help="lockstep drain: read every step's tokens back "
                         "before dispatching the next (continuous mode; "
                         "default is the pipelined drain that overlaps "
                         "token transfer with decode)")
    args = ap.parse_args()

    model = get_model(args.arch, smoke=args.smoke)
    if args.ckpt_dir:
        step, tree, _ = CheckpointManager(args.ckpt_dir).restore()
        params = jax.tree.map(jnp.asarray, tree["params"])
        print(f"[serve] restored step {step} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.key(0))
        print("[serve] random-init params (demo mode)")

    if sum(map(bool, (args.mp_plan, args.calibration, args.registry))) > 1:
        raise SystemExit("--mp-plan, --calibration and --registry are "
                         "mutually exclusive")
    if (args.tau is not None or args.objective is not None) \
            and not (args.calibration or args.registry):
        raise SystemExit("--tau/--objective select a serve-time solve and "
                         "require --calibration or --registry")
    if args.adaptive_tau is not None:
        if not (args.calibration or args.registry):
            raise SystemExit("--adaptive-tau re-solves under load and needs "
                             "--calibration or --registry")
        if not args.continuous:
            raise SystemExit("--adaptive-tau drives the continuous engine; "
                             "pass --continuous")
    if (args.inject_faults or args.guardrail_every) and not args.continuous:
        raise SystemExit("--inject-faults/--guardrail-every drive the "
                         "continuous engine; pass --continuous")
    plan = None
    controller = None
    bundle = src = None
    if args.calibration:
        bundle, src = CalibrationBundle.load(args.calibration), args.calibration
    elif args.registry:
        bundle, src = _registry_bundle(model, params, args.registry)
    if bundle is not None:
        _check_bundle_ops(model, params, bundle, src)
        if args.adaptive_tau is not None:
            from repro.serve import AdaptiveMPController
            controller = AdaptiveMPController.from_bundle(
                bundle, args.adaptive_tau,
                n_levels=args.adaptive_levels, factor=args.adaptive_factor,
                objective=args.objective or "ET",
                every=args.adaptive_every, dwell=args.adaptive_dwell,
                queue_high=args.adaptive_queue_high,
                queue_low=args.adaptive_queue_low)
            base = controller.plan
            print(f"[serve] adaptive MP: tau ladder "
                  f"{[f'{t:g}' for t in controller.taus]} (base plan "
                  f"quantizes {base.n_quantized} ops, "
                  f"tier {base.meta.get('gain_tier')})")
        else:
            plan = _solve_from_bundle(bundle, args, src)
    elif args.mp_plan:
        plan = MPPlan.load(args.mp_plan)
        print(f"[serve] MP plan: {plan.n_quantized} ops quantized "
              f"(objective {plan.objective}, tau {plan.tau})")
        unknown = _plan_unknown_ops(model, params, plan)
        if unknown:
            print(f"[serve] WARNING: {len(unknown)} plan ops not in this "
                  f"model (e.g. {sorted(unknown)[:3]}) — they will NOT "
                  f"apply; was the plan solved for a different arch?")

    if args.mesh and not args.continuous:
        raise SystemExit("--mesh shards the continuous-batching engine; "
                         "pass --continuous")

    if args.continuous:
        from repro.launch.mesh import mesh_from_spec
        mesh = mesh_from_spec(args.mesh)
        if mesh is not None:
            print(f"[serve] mesh: {dict(mesh.shape)}")
        max_len = args.prompt_len + args.new_tokens
        n_blocks = args.n_blocks
        if n_blocks == "auto":
            from repro.serve.cache_pool import PagedCachePool
            if args.dense_slots:
                raise SystemExit("--n-blocks auto sizes the paged pool; "
                                 "drop --dense-slots")
            data_shards = mesh.shape["data"] if mesh is not None else 1
            profile = [(args.prompt_len, args.new_tokens)] * args.requests
            n_blocks = PagedCachePool.size_n_blocks(
                profile, args.n_slots, args.block_size,
                data_shards=data_shards)
            worst, _, _ = PagedCachePool.plan_blocks(
                args.n_slots, max_len, args.block_size,
                data_shards=data_shards)
            print(f"[serve] auto-sized paged pool: {n_blocks} blocks "
                  f"(worst case {worst}) from {args.requests}-request "
                  f"profile at p95 live demand x1.25 headroom")
        elif n_blocks is not None:
            n_blocks = int(n_blocks)
        injector = None
        if args.inject_faults:
            from repro.serve import FaultInjector
            injector = FaultInjector.parse(args.inject_faults)
            print(f"[serve] fault injection: {len(injector.specs)} scheduled "
                  f"({args.inject_faults})")
        guardrail = None
        if args.guardrail_every:
            from repro.serve import NumericalGuardrail
            guardrail = NumericalGuardrail(every=args.guardrail_every,
                                           margin=args.guardrail_margin)
            print(f"[serve] guardrail: shadow step every "
                  f"{args.guardrail_every} decode steps, breach at "
                  f"{args.guardrail_margin:g}x the plan's loss-MSE budget")
        eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                       max_len=max_len, mp=plan,
                                       paged=not args.dense_slots,
                                       block_size=args.block_size,
                                       n_blocks=n_blocks,
                                       chunk_len=args.chunk_len,
                                       chunk_budget=args.chunk_budget,
                                       paged_attn=args.paged_attn,
                                       mesh=mesh,
                                       prefix_cache=(False
                                                     if args.no_prefix_cache
                                                     else None),
                                       adaptive=controller,
                                       faults=injector,
                                       max_retries=args.max_retries,
                                       guardrail=guardrail)
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        tokens=rng.integers(0, model.cfg.vocab_size,
                                            args.prompt_len).astype(np.int32),
                        max_new_tokens=args.new_tokens,
                        arrival=i * args.arrival_every)
                for i in range(args.requests)]
        # compile warm-up must not consume the fault schedule or trip
        # the guardrail's one-shot breach state
        eng.faults, eng.guardrail = None, None
        eng.serve(params, reqs[:1], sync=args.sync_engine)  # compile
        eng.faults, eng.guardrail = injector, guardrail
        out = eng.serve(params, reqs, sync=args.sync_engine)
        ttfts = sorted(r.ttft_s for r in out.results.values())
        p50 = f"{ttfts[len(ttfts)//2]*1e3:.2f} ms" if ttfts else "n/a"
        print(f"[serve] continuous: {args.requests} reqs via {args.n_slots} "
              f"slots | {out.n_steps} decode steps | "
              f"{out.tokens_per_s:.1f} tok/s | TTFT p50 {p50}")
        c = out.counters
        mode = "sync (lockstep)" if c["sync"] else "pipelined"
        print(f"[serve] host/device overlap [{mode}]: "
              f"{c['host_blocked_s_per_step'] * 1e6:.1f} us/step host-blocked "
              f"| {c['n_readbacks']} readbacks (batch mean "
              f"{c['readback_batch_mean']:.1f}, max {c['readback_batch_max']})"
              f" | device ran {c['steps_in_flight_peak']} steps ahead at peak")
        if c.get("paged"):
            print(f"[serve] paged KV: block_size {c['block_size']} | "
                  f"{c['peak_blocks_in_use']}/{c['n_blocks'] - 1} blocks at "
                  f"peak | peak KV {c['peak_kv_bytes'] / 1e6:.2f} MB vs dense "
                  f"{c['dense_kv_bytes'] / 1e6:.2f} MB | "
                  f"{c['blocked_admissions']} blocked admissions")
            if out.n_steps:
                print(f"[serve] decode attention ({c['paged_attn']}): "
                      f"{c['decode_attn_bytes_read'] / max(out.n_steps, 1) / 1e6:.3f} "
                      f"MB/step KV read (fused model "
                      f"{c['decode_attn_bytes_fused_model'] / 1e6:.2f} MB vs "
                      f"gather {c['decode_attn_bytes_gather_model'] / 1e6:.2f}"
                      f" MB over the drain)")
        print(f"[serve] prefill: {c['prefill_chunks']} chunk steps | "
              f"{c['prefill_buckets']} compile buckets for "
              f"{c['distinct_prompt_lens']} prompt lengths | "
              f"{c['decode_stall_steps']} decode-stall chunk steps "
              f"(longest run {c['max_decode_stall_run']})")
        if c.get("prefix_cache"):
            print(f"[serve] prefix cache: {c['prefix_hit_requests']} hit "
                  f"requests | {c['prefix_hit_tokens']} prompt tokens "
                  f"skipped | {c['cow_forks']} COW forks | "
                  f"{c['preemptions']} preemptions")
        if "adaptive" in c:
            a = c["adaptive"]
            print(f"[serve] adaptive MP: {a['downshifts']} downshifts / "
                  f"{a['restores']} restores over taus {a['taus']} | "
                  f"final tau {a['final_tau']:g} (level {a['final_level']}) "
                  f"| swaps at steps "
                  f"{[s['step'] for s in a['swaps']] or 'none'}")
        f = c.get("faults")
        if f and (f["seen"] or f["injected"]):
            inj_desc = ", ".join(f"{k}x{v}" for k, v in
                                 sorted(f["injected"].items())) or "none"
            print(f"[serve] faults: injected {inj_desc} | "
                  f"{f['contained']} contained / {f['retries']} retries / "
                  f"{f['failed']} failed | "
                  f"{f['quarantined_blocks']} blocks quarantined | "
                  f"kernel faults {f['kernel_faults']}"
                  + (" | degraded fused->gather"
                     if f["degraded_paged_attn"] else ""))
        g = c.get("guardrail")
        if g:
            print(f"[serve] guardrail: {g['checks']} shadow checks | "
                  f"{g['breaches']} breaches | last MSE "
                  f"{g['last_mse'] if g['last_mse'] is not None else 'n/a'}"
                  + (f" | restored base plan at step {g['restored_at']}"
                     if g["restored_at"] is not None else ""))
        n_failed = sum(1 for r in out.results.values()
                       if r.status == "failed")
        n_retried = sum(1 for r in out.results.values()
                        if r.status == "retried")
        if n_failed or n_retried:
            print(f"[serve] degraded results: {n_retried} retried "
                  f"(bit-identical after re-prefill) | {n_failed} failed "
                  f"(partial tokens returned)")
    else:
        eng = ServeEngine(model, mp=plan, donate=False)
        prompt = {"tokens": jax.random.randint(jax.random.key(1),
                                               (args.batch, args.prompt_len), 0,
                                               model.cfg.vocab_size)}
        eng.generate(params, dict(prompt), max_new_tokens=2)  # compile
        out = eng.generate(params, dict(prompt), max_new_tokens=args.new_tokens)
        print(f"[serve] TTFT {out.ttft_s*1e3:.2f} ms | "
              f"decode {out.tokens_per_s:.1f} tok/s | "
              f"batch {args.batch} x {args.new_tokens} new tokens")


if __name__ == "__main__":
    main()
