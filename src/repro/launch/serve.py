"""Serving launcher: one-shot batch or continuous-batching serving under an
optional MP plan.

    # one-shot (the paper's TTFT measurement harness)
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_1b --smoke \
        --mp-plan plan.json --batch 4 --new-tokens 16

    # continuous batching: staggered arrivals drain through cache slots
    PYTHONPATH=src python -m repro.launch.serve --arch llama3_1b --smoke \
        --continuous --n-slots 4 --requests 12 --arrival-every 2

Loads params from a checkpoint directory if given, else random-init (smoke
demos). An ``--mp-plan`` json (saved by ``MPPlan.save``) flows straight into
either engine. Reports TTFT (the paper's measured quantity) and decode
throughput.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.mpconfig import MPPlan
from repro.models.registry import get_model
from repro.serve import ContinuousBatchingEngine, Request, ServeEngine


def _plan_unknown_ops(model, params, plan: MPPlan) -> set:
    """Abstract-trace the serving prefill and flag plan ops this model lacks."""
    from repro.models.encdec import EncDec
    from repro.quant.qops import QuantContext
    if isinstance(model, EncDec):
        return set()  # encoder-decoder serving keeps its own op namespace
    registry: list = []
    ctx = QuantContext(mode="plain", registry=registry)
    tokens = jax.ShapeDtypeStruct((1, 8), jnp.int32)
    caches = model.init_cache(1, 16, abstract=True)
    jax.eval_shape(lambda p, t, c: model.prefill(p, t, c, ctx),
                   params, tokens, caches)
    return plan.unknown_ops({op.name for op in registry})


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mp-plan", default=None, help="MPPlan json path")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--continuous", action="store_true",
                    help="serve a staggered request stream instead of one batch")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="decode steps between request arrivals")
    args = ap.parse_args()

    model = get_model(args.arch, smoke=args.smoke)
    if args.ckpt_dir:
        step, tree, _ = CheckpointManager(args.ckpt_dir).restore()
        params = jax.tree.map(jnp.asarray, tree["params"])
        print(f"[serve] restored step {step} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.key(0))
        print("[serve] random-init params (demo mode)")

    plan = None
    if args.mp_plan:
        plan = MPPlan.load(args.mp_plan)
        print(f"[serve] MP plan: {plan.n_quantized} ops quantized "
              f"(objective {plan.objective}, tau {plan.tau})")
        unknown = _plan_unknown_ops(model, params, plan)
        if unknown:
            print(f"[serve] WARNING: {len(unknown)} plan ops not in this "
                  f"model (e.g. {sorted(unknown)[:3]}) — they will NOT "
                  f"apply; was the plan solved for a different arch?")

    if args.continuous:
        max_len = args.prompt_len + args.new_tokens
        eng = ContinuousBatchingEngine(model, n_slots=args.n_slots,
                                       max_len=max_len, mp=plan)
        rng = np.random.default_rng(1)
        reqs = [Request(rid=i,
                        tokens=rng.integers(0, model.cfg.vocab_size,
                                            args.prompt_len).astype(np.int32),
                        max_new_tokens=args.new_tokens,
                        arrival=i * args.arrival_every)
                for i in range(args.requests)]
        eng.serve(params, reqs[:1])  # compile
        out = eng.serve(params, reqs)
        ttfts = sorted(r.ttft_s for r in out.results.values())
        p50 = f"{ttfts[len(ttfts)//2]*1e3:.2f} ms" if ttfts else "n/a"
        print(f"[serve] continuous: {args.requests} reqs via {args.n_slots} "
              f"slots | {out.n_steps} decode steps | "
              f"{out.tokens_per_s:.1f} tok/s | TTFT p50 {p50}")
    else:
        eng = ServeEngine(model, mp=plan, donate=False)
        prompt = {"tokens": jax.random.randint(jax.random.key(1),
                                               (args.batch, args.prompt_len), 0,
                                               model.cfg.vocab_size)}
        eng.generate(params, dict(prompt), max_new_tokens=2)  # compile
        out = eng.generate(params, dict(prompt), max_new_tokens=args.new_tokens)
        print(f"[serve] TTFT {out.ttft_s*1e3:.2f} ms | "
              f"decode {out.tokens_per_s:.1f} tok/s | "
              f"batch {args.batch} x {args.new_tokens} new tokens")


if __name__ == "__main__":
    main()
