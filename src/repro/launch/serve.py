"""Serving launcher: batched generate under an optional MP plan.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3_1b --smoke \
        --mp-plan plan.json --batch 4 --new-tokens 16

Loads params from a checkpoint directory if given, else random-init (smoke
demos). Reports TTFT (the paper's measured quantity) and decode throughput.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core.mpconfig import MPPlan
from repro.models.registry import get_model
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mp-plan", default=None, help="MPPlan json path")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    model = get_model(args.arch, smoke=args.smoke)
    if args.ckpt_dir:
        step, tree, _ = CheckpointManager(args.ckpt_dir).restore()
        params = jax.tree.map(jnp.asarray, tree["params"])
        print(f"[serve] restored step {step} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.key(0))
        print("[serve] random-init params (demo mode)")

    mp = None
    if args.mp_plan:
        plan = MPPlan.load(args.mp_plan)
        mp = plan.assignment
        print(f"[serve] MP plan: {plan.n_quantized} ops quantized "
              f"(objective {plan.objective}, tau {plan.tau})")

    eng = ServeEngine(model, mp=mp, donate=False)
    prompt = {"tokens": jax.random.randint(jax.random.key(1),
                                           (args.batch, args.prompt_len), 0,
                                           model.cfg.vocab_size)}
    eng.generate(params, dict(prompt), max_new_tokens=2)  # compile
    out = eng.generate(params, dict(prompt), max_new_tokens=args.new_tokens)
    print(f"[serve] TTFT {out.ttft_s*1e3:.2f} ms | "
          f"decode {out.tokens_per_s:.1f} tok/s | "
          f"batch {args.batch} x {args.new_tokens} new tokens")


if __name__ == "__main__":
    main()
