"""Step-function builders shared by the trainer, the serving engine and the
multi-pod dry-run. All steps take/return pure pytrees so they jit/lower
cleanly with explicit shardings.
"""
from __future__ import annotations

import functools
import threading
import weakref
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.mpconfig import as_assignment
from repro.quant.qops import QuantContext
from repro.train import optim

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_paged_decode_step", "make_eval_step",
           "make_bucketed_prefill_step", "make_chunked_prefill_step",
           "make_dense_chunked_prefill_step",
           "get_serving_step", "greedy_next_token", "merge_first_tokens"]


def _split_micro(batch: dict, n_micro: int) -> dict:
    # NOTE: no sharding constraint here — a wsc on scan xs makes partial-eval
    # stack an f32 copy of the layer-scan carry (see models/lm.py note). The
    # batch-dim constraint inside the model (`_backbone` entry) keeps each
    # microbatch data-sharded.
    def r(x):
        assert x.shape[0] % n_micro == 0, (x.shape, n_micro)
        return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(model, opt_cfg: optim.OptConfig,
                    n_microbatches: int = 1, mp: Optional[dict] = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""
    mp = as_assignment(mp)
    ctx = QuantContext(mode="mp", mp=mp) if mp else QuantContext()

    def loss_fn(p, b):
        return model.loss(p, b, ctx)

    def train_step(params, opt_state, batch):
        if n_microbatches > 1:
            micro = _split_micro(batch, n_microbatches)

            def acc(carry, mb):
                loss_sum, g_sum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (loss_sum + loss, g_sum), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_state, metrics = optim.apply_updates(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_eval_step(model, mp: Optional[dict] = None):
    mp = as_assignment(mp)
    ctx = QuantContext(mode="mp", mp=mp) if mp else QuantContext()

    def eval_step(params, batch):
        return model.loss(params, batch, ctx)

    return eval_step


def _serving_ctx(mp) -> QuantContext:
    """One QuantContext policy for every serving step (prefill — one-shot,
    bucketed and chunked — plus dense and paged decode): per-*token*
    activation scales, so greedy tokens depend neither on which requests
    share the batch, nor on how a prompt is split into prefill chunks, nor
    on bucket padding. Shared so no two serving steps can ever diverge."""
    mp = as_assignment(mp)
    return (QuantContext(mode="mp", mp=mp, act_scale_token=True) if mp
            else QuantContext())


def make_prefill_step(model, mp: Optional[dict] = None):
    """(params, caches, batch) -> (last-token logits, caches)."""
    ctx = _serving_ctx(mp)

    from repro.models.encdec import EncDec

    if isinstance(model, EncDec):
        def prefill_step(params, caches, batch):
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 caches, ctx)
    else:
        def prefill_step(params, caches, batch):
            return model.prefill(params, batch["tokens"], caches, ctx,
                                 prefix_embeds=batch.get("prefix_embeds"))
    return prefill_step


def make_bucketed_prefill_step(model, mp: Optional[dict] = None):
    """(params, caches, tokens, start, valid) -> (last-valid logits, caches).

    Dense bucketed prefill: ``tokens`` (B, Lb) is padded to a power-of-two
    bucket, ``valid`` (B,) counts real tokens per row, ``start`` (B,) is 0
    for rows being prefilled (nonzero rows pass through untouched). Compiled
    once per bucket length — shared by the one-shot engine and the dense
    continuous engine, which both used to compile per distinct prompt length.
    """
    ctx = _serving_ctx(mp)

    def prefill_step(params, caches, tokens, start, valid):
        return model.prefill_chunk(params, tokens, caches, ctx,
                                   start_pos=start, valid_len=valid)

    return prefill_step


def make_chunked_prefill_step(model, mp: Optional[dict] = None):
    """(params, caches, tokens, start, valid, block_tables) -> (logits, caches).

    The paged twin of :func:`make_bucketed_prefill_step`: the chunk's K/V is
    written straight into the pool's physical blocks (paged prefill) and a
    prompt longer than the chunk budget resumes at ``start`` on the next
    call, attending over every earlier chunk through the block tables.
    ``start`` need not trace back to a chunk this step wrote: prefix-cache
    hits and preemption resumes start mid-sequence against table pages
    some *earlier request* populated — correct because the written K/V is a
    pure function of the tokens at or before each position.
    """
    ctx = _serving_ctx(mp)

    def prefill_step(params, caches, tokens, start, valid, block_tables):
        return model.prefill_chunk(params, tokens, caches, ctx,
                                   start_pos=start, valid_len=valid,
                                   block_tables=block_tables)

    return prefill_step


def make_dense_chunked_prefill_step(model, mp: Optional[dict] = None):
    """(params, caches, tokens, start, valid) -> (logits, caches).

    Chunked prefill over *dense* (non-paged) per-slot caches. Same contract
    as :func:`make_bucketed_prefill_step`, but ``start`` may be nonzero:
    later chunks of a long prompt resume where the previous chunk stopped,
    attending over the earlier chunks through the slot's own ring. Windowed
    layers need their rings widened by the chunk length
    (``init_cache(..., chunk_extra=chunk_len)``) — a ``window``-sized ring
    truncates chunked prefill whenever ``window`` is not chunk-aligned.
    """
    ctx = _serving_ctx(mp)

    def prefill_step(params, caches, tokens, start, valid):
        return model.prefill_chunk(params, tokens, caches, ctx,
                                   start_pos=start, valid_len=valid,
                                   chunk_ring=True)

    return prefill_step


def make_decode_step(model, mp: Optional[dict] = None):
    """(params, caches, token, pos) -> (logits, caches).

    ``pos`` is a scalar int32 for lock-step batches, or — for decoder-only
    LMs — a (B,) int32 vector of per-slot positions so a continuous-batching
    engine can decode sequences at different depths in one step.
    """
    ctx = _serving_ctx(mp)

    def decode_step(params, caches, token, pos):
        return model.decode_step(params, token, pos, caches, ctx)

    return decode_step


# ---------------------------------------------------------------------------
# memoized serving-step compile cache
# ---------------------------------------------------------------------------

# model -> {(kind, mp_key, paged_attn, donate): jitted step}. Keyed weakly on
# the model object so engines built over the same model (the common pattern in
# tests: one module-scoped model, many engine instances) share one jitted
# program per step flavor instead of re-jitting a fresh closure each time —
# which re-ran the interpret-mode Pallas kernel compile in every paged serve
# test and dominated the CPU suite's wall time.
_SERVING_STEPS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_SERVING_STEPS_LOCK = threading.Lock()


def _mp_cache_key(mp):
    mp = as_assignment(mp)
    return None if mp is None else tuple(sorted(mp.items()))


def get_serving_step(model, kind: str, mp=None,
                     paged_attn: Optional[str] = None, donate: bool = False,
                     mesh_layout=None):
    """Memoized ``jax.jit`` of a serving step for ``model``.

    ``kind`` is one of ``prefill`` / ``bucketed_prefill`` /
    ``chunked_prefill`` / ``dense_chunked_prefill`` / ``decode`` /
    ``paged_decode``. Steps are cached per
    (model, kind, MP assignment, paged_attn, donation, mesh layout) so every
    engine over the same model reuses one compiled program per input shape.
    ``mp`` may be an assignment dict or an ``MPPlan``.

    ``mesh_layout`` (a ``ServingMeshLayout``) makes the step mesh-aware: the
    layout contextvar is active around every call — in particular at trace
    time, where the paged-attention dispatch reads it (shard_map vs gather)
    — and the call runs inside ``with mesh:`` so activation shard hints see
    the physical mesh. Each distinct layout gets its own compiled program.
    """
    builders = {
        "prefill": make_prefill_step,
        "bucketed_prefill": make_bucketed_prefill_step,
        "chunked_prefill": make_chunked_prefill_step,
        "dense_chunked_prefill": make_dense_chunked_prefill_step,
        "decode": make_decode_step,
        "paged_decode": make_paged_decode_step,
    }
    if kind not in builders:
        raise ValueError(f"unknown serving step kind {kind!r}")
    if paged_attn is not None and kind != "paged_decode":
        raise ValueError("paged_attn only applies to kind='paged_decode'")
    key = (kind, _mp_cache_key(mp), paged_attn, bool(donate), mesh_layout)
    with _SERVING_STEPS_LOCK:
        cache = _SERVING_STEPS.setdefault(model, {})
        fn = cache.get(key)
        if fn is None:
            if kind == "paged_decode":
                raw = make_paged_decode_step(model, mp=mp,
                                             paged_attn=paged_attn or "fused")
            else:
                raw = builders[kind](model, mp=mp)
            jitted = jax.jit(raw, donate_argnums=(1,) if donate else ())
            if mesh_layout is None:
                fn = jitted
            else:
                from repro.distributed.sharding import serving_layout_scope

                @functools.wraps(jitted)
                def fn(*a, __jitted=jitted, __layout=mesh_layout, **kw):
                    with __layout.mesh, serving_layout_scope(__layout):
                        return __jitted(*a, **kw)
            cache[key] = fn
    return fn


@jax.jit
def greedy_next_token(logits):
    """(B, T, V) logits -> (B,) int32 greedy next token from the last step.

    Jitted separately from the model step on purpose: the argmax runs as its
    own XLA program over the step's *output* logits, so moving it on-device
    (the async engine's no-readback path) cannot perturb the step's numerics
    — the tokens are bit-identical to a host-side ``np.argmax`` readback.
    """
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


@jax.jit
def nonfinite_rows(logits):
    """(B, T, V) logits -> (B,) bool: row's last-step logits hold a NaN/inf.

    The serving engines' numerical tripwire: one tiny reduction jitted over
    the step's *output* (like :func:`greedy_next_token`, so it cannot
    perturb the step's numerics), whose result rides the same batched
    device_get as the token vector — flagging a poisoned KV page or a
    saturated projection costs no extra readback."""
    return jnp.logical_not(
        jnp.all(jnp.isfinite(logits[:, -1]), axis=-1))


@jax.jit
def shadow_logit_mse(logits, ref_logits, row):
    """fp32 mean-squared error between one row's last-step logits under the
    active plan and under the high-precision shadow step — the measured
    quantity the tau-anchored guardrail compares against the plan's
    loss-MSE budget (see ``serve/adaptive.py``)."""
    a = logits[row, -1].astype(jnp.float32)
    b = ref_logits[row, -1].astype(jnp.float32)
    return jnp.mean(jnp.square(a - b))


@jax.jit
def merge_first_tokens(cur_tok, new_tok, mask):
    """Scatter freshly-prefilled rows' first tokens into the device-resident
    decode input: rows where ``mask`` is set take ``new_tok``, others keep
    ``cur_tok``. (B, 1) int32, stays on device."""
    return jnp.where(mask[:, None], new_tok[:, None], cur_tok)


def make_paged_decode_step(model, mp: Optional[dict] = None,
                           paged_attn: str = "fused"):
    """(params, caches, token, pos, block_tables) -> (logits, caches).

    The paged twin of :func:`make_decode_step`: ``caches`` hold block-major
    attention K/V owned by a ``PagedCachePool`` and ``block_tables`` is the
    (B, max_blocks) int32 map from each decode row's logical pages to
    physical blocks (-1 = unallocated; vacant rows are all -1). Per-row
    lengths are derived inside the model from the ``pos`` vector (pos + 1).

    ``paged_attn`` selects the paged attention implementation: ``"fused"``
    (default) attends block-major K/V in place via the Pallas
    paged-attention kernel — per-step attention HBM traffic proportional to
    live tokens; ``"gather"`` keeps the reference path that materializes
    the logical (B, max_blocks * block_size) K/V per layer. Layers whose
    attention BGEMMs carry an MP format always use gather (exact quantized
    semantics) regardless of this switch."""
    ctx = _serving_ctx(mp)

    def decode_step(params, caches, token, pos, block_tables):
        return model.decode_step(params, token, pos, caches, ctx,
                                 block_tables=block_tables,
                                 paged_attn=paged_attn)

    return decode_step
