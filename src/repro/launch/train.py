"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2p5_3b --smoke \
        --steps 100 --mesh-data 1 --mesh-model 1

Production posture: build the mesh, derive shardings from the arch's param
specs, auto-resume from the newest valid checkpoint, watchdog stragglers,
checkpoint atomically. On a real cluster each host runs this same entrypoint
under `jax.distributed.initialize` (flags pass through); in this container it
drives the local device set.
"""
from __future__ import annotations

import argparse

import jax

from repro.data.synthetic import SyntheticConfig, SyntheticLM
from repro.launch.mesh import make_local_mesh
from repro.models.registry import get_model
from repro.train.optim import OptConfig, select_optimizer
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints/launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--abort-on-straggler", action="store_true")
    args = ap.parse_args()

    model = get_model(args.arch, smoke=args.smoke)
    print(f"[train] arch={args.arch} params={model.n_params():,} "
          f"mesh=({args.mesh_data},{args.mesh_model})")
    mesh = make_local_mesh(args.mesh_data, args.mesh_model)
    data = SyntheticLM(SyntheticConfig(vocab_size=model.cfg.vocab_size,
                                       batch=args.batch, seq_len=args.seq))
    opt = select_optimizer(
        model.n_params(),
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                  total_steps=args.steps))
    tr = Trainer(model, opt, mesh,
                 TrainerConfig(total_steps=args.steps,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir, log_every=10,
                               n_microbatches=args.microbatches,
                               abort_on_straggler=args.abort_on_straggler,
                               metrics_path=f"{args.ckpt_dir}/metrics.jsonl"))
    params, _, last = tr.fit(data)
    print(f"[train] done: final loss {last:.4f} (ckpts: {args.ckpt_dir})")


if __name__ == "__main__":
    main()
