"""Encoder-decoder transformer (Whisper-style backbone).

Per the assignment spec the audio frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (B, T_enc, d_model) — the conv
mel-spectrogram stem is out of scope. Positions are sinusoidal computed on
the fly (shape-flexible up to the 32k cells; deviation from Whisper's learned
decoder positions is noted in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import layers as L
from repro.nn.spec import ParamSpec, abstract_params, init_params, param_count
from repro.quant.qops import QuantContext


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    n_enc_layers: int
    n_dec_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    activation: str = "gelu"
    norm: str = "layernorm"
    loss_chunk: int = 1024
    flash_min_seq: int = 4096
    flash_block: int = 1024
    scan_layers: bool = False  # enc-dec stacks are small; unrolled only
    remat: bool = False
    dtype: str = "bfloat16"

    @property
    def n_layers(self) -> int:  # uniform API with LMConfig
        return self.n_enc_layers + self.n_dec_layers

    @property
    def enc_attn(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.d_head, causal=False, rope_theta=None,
                            flash_min_seq=self.flash_min_seq,
                            flash_block=self.flash_block)

    @property
    def dec_attn(self) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.d_head, causal=True, rope_theta=10000.0,
                            flash_min_seq=self.flash_min_seq,
                            flash_block=self.flash_block)


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDec:
    # serving capability flags (engines dispatch on these, not on isinstance):
    # init_cache(batch, max_len, enc_len) needs the encoder length for the
    # pre-computed cross-attention K/V
    cache_needs_enc_len = True

    def __init__(self, cfg: EncDecConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # ---------------- specs ----------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict = {
            "embed/w": ParamSpec((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), init="normal"),
        }
        specs.update(L.norm_specs("enc_final_norm", cfg.d_model, cfg.norm))
        specs.update(L.norm_specs("dec_final_norm", cfg.d_model, cfg.norm))
        for i in range(cfg.n_enc_layers):
            pre = f"enc/{i}"
            specs.update(L.norm_specs(f"{pre}/attn_norm", cfg.d_model, cfg.norm))
            specs.update(L.attn_specs(f"{pre}/attn", cfg.enc_attn))
            specs.update(L.norm_specs(f"{pre}/mlp_norm", cfg.d_model, cfg.norm))
            specs.update(L.mlp_specs(f"{pre}/mlp", cfg.d_model, cfg.d_ff,
                                     cfg.activation))
        for i in range(cfg.n_dec_layers):
            pre = f"dec/{i}"
            specs.update(L.norm_specs(f"{pre}/attn_norm", cfg.d_model, cfg.norm))
            specs.update(L.attn_specs(f"{pre}/attn", cfg.dec_attn))
            specs.update(L.norm_specs(f"{pre}/cross_norm", cfg.d_model, cfg.norm))
            specs.update(L.attn_specs(f"{pre}/cross", cfg.enc_attn))
            specs.update(L.norm_specs(f"{pre}/mlp_norm", cfg.d_model, cfg.norm))
            specs.update(L.mlp_specs(f"{pre}/mlp", cfg.d_model, cfg.d_ff,
                                     cfg.activation))
        return specs

    def init(self, key):
        return init_params(key, self.param_specs())

    def n_params(self) -> int:
        return param_count(self.param_specs())

    def abstract_params(self, shardings: Optional[dict] = None) -> dict:
        return abstract_params(self.param_specs(), shardings)

    # ---------------- encoder ----------------
    def encode(self, params: dict, frames: jax.Array, ctx: QuantContext):
        cfg = self.cfg
        B, T, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        h = frames.astype(self.dtype) + _sinusoid(positions, cfg.d_model).astype(self.dtype)
        for i in range(cfg.n_enc_layers):
            def body(p, h_):
                hn = L.apply_norm(p["attn_norm"], h_, cfg.norm)
                y, _ = L.attention(p["attn"], ctx, f"enc/{i}/attn",
                                   cfg.enc_attn, hn, positions)
                h_ = h_ + y
                hn = L.apply_norm(p["mlp_norm"], h_, cfg.norm)
                return h_ + L.apply_mlp(p["mlp"], ctx, f"enc/{i}/mlp", hn,
                                        cfg.activation)
            if cfg.remat:
                body = jax.checkpoint(body)
            h = body(params["enc"][str(i)], h)
        return L.apply_norm(params["enc_final_norm"], h, cfg.norm)

    # ---------------- decoder ----------------
    def _decoder(self, params: dict, ctx: QuantContext, tokens: jax.Array,
                 enc_out: Optional[jax.Array], *, caches: Optional[dict] = None,
                 cache_pos=None):
        cfg = self.cfg
        B, T = tokens.shape
        if cache_pos is None:
            positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        else:
            positions = jnp.broadcast_to(cache_pos[None, None], (B, T)).astype(jnp.int32)
        h = jnp.take(params["embed"]["w"], tokens, axis=0).astype(self.dtype)
        h = h + _sinusoid(positions, cfg.d_model).astype(self.dtype)
        new_caches = {} if caches is not None else None
        for i in range(cfg.n_dec_layers):
            self_c = None if caches is None else caches[f"dec/{i}/self"]
            cross_c = None if caches is None else caches[f"dec/{i}/cross"]

            def body(p, h_, self_c_, cross_c_):
                hn = L.apply_norm(p["attn_norm"], h_, cfg.norm)
                y, self_new = L.attention(p["attn"], ctx, f"dec/{i}/attn",
                                          cfg.dec_attn, hn, positions,
                                          cache=self_c_, cache_pos=cache_pos)
                h_ = h_ + y
                hn = L.apply_norm(p["cross_norm"], h_, cfg.norm)
                if enc_out is not None:
                    y, _ = L.attention(p["cross"], ctx, f"dec/{i}/cross",
                                       cfg.enc_attn, hn, positions,
                                       kv_x=enc_out, cross=True)
                    if new_caches is not None:
                        cross_c_ = L.cross_kv(p["cross"], ctx,
                                              f"dec/{i}/cross", cfg.enc_attn,
                                              enc_out)
                else:
                    y, _ = L.attention(p["cross"], ctx, f"dec/{i}/cross",
                                       cfg.enc_attn, hn, positions,
                                       cache=cross_c_, cross=True)
                h_ = h_ + y
                hn = L.apply_norm(p["mlp_norm"], h_, cfg.norm)
                h_ = h_ + L.apply_mlp(p["mlp"], ctx, f"dec/{i}/mlp", hn,
                                      cfg.activation)
                return h_, self_new, cross_c_

            if cfg.remat and caches is None:
                body = jax.checkpoint(body)
            h, self_new, cross_new = body(params["dec"][str(i)], h, self_c,
                                          cross_c)
            if new_caches is not None:
                new_caches[f"dec/{i}/self"] = self_new
                new_caches[f"dec/{i}/cross"] = cross_new
        h = L.apply_norm(params["dec_final_norm"], h, cfg.norm)
        return h, new_caches

    def _head(self, params: dict, ctx: QuantContext, h: jax.Array):
        from repro.quant import qops
        return qops.linear(ctx, "lm_head", h, params["embed"]["w"])

    # ---------------- public API ----------------
    def apply(self, params, batch, ctx: QuantContext):
        enc_out = self.encode(params, batch["frames"], ctx)
        h, _ = self._decoder(params, ctx, batch["tokens"], enc_out)
        return self._head(params, ctx, h)

    def loss(self, params: dict, batch: dict, ctx: QuantContext) -> jax.Array:
        enc_out = self.encode(params, batch["frames"], ctx)
        h, _ = self._decoder(params, ctx, batch["tokens"], enc_out)
        from repro.nn.losses import chunked_ce_loss
        return chunked_ce_loss(lambda hi: self._head(params, ctx, hi), h,
                               batch["labels"], batch.get("weights"),
                               self.cfg.loss_chunk,
                               no_scan=(ctx.mode == "probe"))

    def cache_specs(self, batch: int, max_len: int, enc_len: int) -> dict:
        cfg = self.cfg
        specs = {}
        for i in range(cfg.n_dec_layers):
            for k, ps in L.kv_cache_spec(cfg.dec_attn, batch, max_len,
                                         self.dtype).items():
                specs[f"dec/{i}/self/{k}"] = ps
            for k in ("k", "v"):
                specs[f"dec/{i}/cross/{k}"] = ParamSpec(
                    (batch, enc_len, cfg.n_kv_heads, cfg.d_head),
                    ("act_batch", None, "heads", None), self.dtype, "zeros")
        return specs

    def init_cache(self, batch: int, max_len: int, enc_len: int) -> dict:
        flat = {}
        for k, s in self.cache_specs(batch, max_len, enc_len).items():
            if k.endswith("/pos"):
                flat[k] = jnp.full(s.shape, -1, jnp.int32)
            else:
                flat[k] = jnp.zeros(s.shape, s.dtype)
        caches = {}
        for key, v in flat.items():
            layer, leaf = key.rsplit("/", 1)
            caches.setdefault(layer, {})[leaf] = v
        return caches

    def prefill(self, params: dict, frames: jax.Array, tokens: jax.Array,
                caches: dict, ctx: QuantContext):
        enc_out = self.encode(params, frames, ctx)
        h, caches = self._decoder(params, ctx, tokens, enc_out, caches=caches)
        return self._head(params, ctx, h[:, -1:]), caches

    def decode_step(self, params: dict, token: jax.Array, pos: jax.Array,
                    caches: dict, ctx: QuantContext):
        h, caches = self._decoder(params, ctx, token, None, caches=caches,
                                  cache_pos=pos)
        return self._head(params, ctx, h), caches
