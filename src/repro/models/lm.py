"""Decoder-only LM covering the dense / MoE / MLA / SSM / hybrid families.

One config dataclass + one model class expresses all assigned architectures
via a per-layer ``block_types`` pattern:

* ``attn``   — attention + (MLP | MoE | nothing if d_ff==0)
* ``mla``    — DeepSeek-style latent attention + (MLP | MoE)
* ``mamba``  — Mamba-2 SSD block (+ optional MLP)
* ``hybrid`` — parallel attention & mamba heads sharing the input norm (Hymba)

Two execution modes:
* unrolled (default) — every layer has its own params and op names
  (``layers/3/attn/q_proj``); required for per-layer MP and calibration.
* ``scan_layers=True`` — consecutive layers with the same signature are
  stacked into segments executed with ``jax.lax.scan`` (O(1) HLO size for the
  61-layer dry-runs). Op names are per call-site (``segments/1/attn/q_proj``);
  MP assignments then apply per segment (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn import layers as L
from repro.nn import mamba as M
from repro.nn import moe as MOE
from repro.nn.spec import (ParamSpec, abstract_params, flatten_paths,
                           init_params, param_count, tree_from_flat)
from repro.quant.qops import QuantContext

BIG_WINDOW = 1 << 30  # "no window" sentinel for traced window values


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    d_head: int = 0
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    global_attn_layers: tuple = ()        # layers exempt from the window
    # MLA (block type "mla")
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mla_absorb_decode: bool = False       # latent-space decode (§Perf lever)
    # mlp
    d_ff: int = 0
    activation: str = "swiglu"
    norm: str = "rmsnorm"
    # blocks
    block_types: tuple = ()               # len == n_layers
    moe_layers: tuple = ()                # layer idxs with MoE instead of MLP
    moe: Optional[MOE.MoEConfig] = None
    ssm: Optional[M.SSMConfig] = None
    # head
    tie_embeddings: bool = False
    # multimodal stub (llava / audio): accepts prefix embeddings
    prefix_embed: bool = False
    # MTP (DeepSeek-V3 multi-token prediction) — adds one extra block
    mtp_depth: int = 0
    mtp_weight: float = 0.3
    # infra
    scan_layers: bool = False
    remat: bool = False
    remat_group: int = 8                  # two-level remat group (train scans)
    loss_chunk: int = 1024                # seq chunk for the CE loss
    flash_min_seq: int = 4096
    flash_block: int = 1024
    dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"      # fp8_e4m3 halves decode cache HBM
    # paged KV-cache dequant multipliers (scaled fp8 KV): None | a tuple of
    # (entry, scale) pairs applied to every layer | a per-layer tuple (len
    # n_layers) of such pair-tuples (None entries = unit scales). Entries:
    # "k"/"v" (attention blocks) or "ckv"/"kr" (MLA). Writes divide by the
    # scale before the fp8 cast, reads multiply it back — see
    # repro.quant.kv_scales.calibrate_kv_scales for producing these from a
    # calibration pass. Paged serving only; dense rings ignore scales.
    kv_dequant_scales: Optional[tuple] = None
    # store matmul weights in fp8 (the paper's IP-M objective realized):
    # halves weight HBM + FSDP gather bytes; dequant folds into the GEMM
    param_dtype: str = "bfloat16"

    def __post_init__(self):
        if not self.block_types:
            object.__setattr__(self, "block_types", ("attn",) * self.n_layers)
        assert len(self.block_types) == self.n_layers
        sc = self.kv_dequant_scales
        if sc is not None:
            sc = tuple(sc)
            if self._scales_are_per_layer(sc):
                sc = tuple(None if e is None else
                           tuple((str(n), float(s)) for n, s in e)
                           for e in sc)
                if len(sc) != self.n_layers:
                    raise ValueError(
                        f"per-layer kv_dequant_scales has {len(sc)} entries "
                        f"for {self.n_layers} layers")
                if self.scan_layers and len(set(sc)) > 1:
                    raise ValueError(
                        "scan_layers stacks layers into shared-trace "
                        "segments, so per-layer kv_dequant_scales must be "
                        "uniform — pass one global pair-tuple instead")
            else:
                sc = tuple((str(n), float(s)) for n, s in sc)
            object.__setattr__(self, "kv_dequant_scales", sc)

    @staticmethod
    def _scales_are_per_layer(sc: tuple) -> bool:
        """Global form: ((name, scale), ...); per-layer form: one entry per
        layer, each None or a pair-tuple."""
        first = next((e for e in sc if e is not None), None)
        if first is None:
            return True
        return not (len(first) == 2 and isinstance(first[0], str))

    def kv_scales_for(self, i: Optional[int]) -> Optional[tuple]:
        """Dequant-scale pairs for layer ``i`` (None = unit scales).
        ``i=None`` (scan segments, MTP block) returns the global pairs, or
        None under a per-layer table — per-layer + scan is rejected at
        construction unless uniform."""
        sc = self.kv_dequant_scales
        if sc is None:
            return None
        if self._scales_are_per_layer(sc):
            if i is None:
                return sc[0] if self.scan_layers and sc else None
            return sc[i]
        return sc

    # ---- derived ----
    @property
    def attn_cfg(self) -> L.AttnConfig:
        return self.attn_cfg_for(None)

    def attn_cfg_for(self, i: Optional[int]) -> L.AttnConfig:
        return L.AttnConfig(self.d_model, self.n_heads, self.n_kv_heads,
                            self.d_head, qkv_bias=self.qkv_bias,
                            rope_theta=self.rope_theta,
                            window=self.sliding_window,
                            flash_min_seq=self.flash_min_seq,
                            flash_block=self.flash_block,
                            kv_dequant_scales=self.kv_scales_for(i))

    @property
    def mla_cfg(self) -> L.MLAConfig:
        return self.mla_cfg_for(None)

    def mla_cfg_for(self, i: Optional[int]) -> L.MLAConfig:
        return L.MLAConfig(self.d_model, self.n_heads, self.q_lora_rank,
                           self.kv_lora_rank, self.qk_nope_dim,
                           self.qk_rope_dim, self.v_head_dim, self.rope_theta,
                           flash_min_seq=self.flash_min_seq,
                           flash_block=self.flash_block,
                           absorb_decode=self.mla_absorb_decode,
                           kv_dequant_scales=self.kv_scales_for(i))

    def layer_signature(self, i: int) -> tuple:
        return (self.block_types[i], i in self.moe_layers)

    def window_for(self, i: int) -> Optional[int]:
        if self.sliding_window is None or i in self.global_attn_layers:
            return None
        return self.sliding_window

    def segments(self) -> list:
        """Consecutive layers grouped by signature: [(sig, [idx...]), ...]."""
        segs: list = []
        for i in range(self.n_layers):
            sig = self.layer_signature(i)
            if segs and segs[-1][0] == sig:
                segs[-1][1].append(i)
            else:
                segs.append((sig, [i]))
        return segs


class LM:
    # serving capability flags (engines dispatch on these, not on isinstance)
    cache_needs_enc_len = False
    supports_prefill_chunk = True        # bucketed/chunked prefill available

    def __init__(self, cfg: LMConfig):
        self.cfg = cfg
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------------
    # specs
    # ------------------------------------------------------------------
    def _layer_specs(self, sig: tuple, prefix: str) -> dict:
        cfg = self.cfg
        block, is_moe = sig
        specs: dict = {}
        specs.update(L.norm_specs(f"{prefix}/attn_norm", cfg.d_model, cfg.norm))
        if block == "attn":
            specs.update(L.attn_specs(f"{prefix}/attn", cfg.attn_cfg))
        elif block == "mla":
            specs.update(L.mla_specs(f"{prefix}/attn", cfg.mla_cfg))
        elif block == "mamba":
            specs.update(M.mamba_specs(f"{prefix}/mamba", cfg.ssm))
        elif block == "hybrid":
            specs.update(L.attn_specs(f"{prefix}/attn", cfg.attn_cfg))
            specs.update(M.mamba_specs(f"{prefix}/mamba", cfg.ssm))
        else:
            raise ValueError(block)
        if is_moe:
            specs.update(L.norm_specs(f"{prefix}/mlp_norm", cfg.d_model, cfg.norm))
            specs.update(MOE.moe_specs(f"{prefix}/moe", cfg.d_model, cfg.moe,
                                       cfg.activation))
        elif cfg.d_ff > 0:
            specs.update(L.norm_specs(f"{prefix}/mlp_norm", cfg.d_model, cfg.norm))
            specs.update(L.mlp_specs(f"{prefix}/mlp", cfg.d_model, cfg.d_ff,
                                     cfg.activation))
        return specs

    def _apply_param_dtype(self, specs: dict) -> dict:
        """Store >=2D matmul weights in cfg.param_dtype (fp8 serving)."""
        cfg = self.cfg
        if cfg.param_dtype == "bfloat16":
            return specs
        from repro.quant.formats import get_format
        dt = get_format(cfg.param_dtype).dtype
        out = {}
        for path, ps in specs.items():
            quantizable = (path.endswith("/w") and len(ps.shape) >= 2
                           and not path.startswith("embed"))
            out[path] = (ParamSpec(ps.shape, ps.logical_axes, dt, ps.init,
                                   ps.init_scale) if quantizable else ps)
        return out

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict = {
            "embed/w": ParamSpec((cfg.vocab_size, cfg.d_model),
                                 ("vocab", "embed"), init="normal"),
        }
        specs.update(L.norm_specs("final_norm", cfg.d_model, cfg.norm))
        if not cfg.tie_embeddings:
            specs["lm_head/w"] = ParamSpec((cfg.vocab_size, cfg.d_model),
                                           ("vocab", "embed"),
                                           init="scaled_normal")
        if cfg.scan_layers:
            for s, (sig, idxs) in enumerate(cfg.segments()):
                layer = self._layer_specs(sig, f"segments/{s}")
                for path, ps in layer.items():
                    specs[path] = ParamSpec((len(idxs),) + ps.shape,
                                            ("layers",) + ps.logical_axes,
                                            ps.dtype, ps.init, ps.init_scale)
        else:
            for i in range(cfg.n_layers):
                specs.update(self._layer_specs(cfg.layer_signature(i),
                                               f"layers/{i}"))
        if cfg.mtp_depth > 0:
            specs["mtp/proj/w"] = ParamSpec((cfg.d_model, 2 * cfg.d_model),
                                            ("embed", None), init="scaled_normal")
            specs.update(L.norm_specs("mtp/norm", cfg.d_model, cfg.norm))
            specs.update(self._layer_specs(self.cfg.layer_signature(
                cfg.n_layers - 1), "mtp/block"))
        return self._apply_param_dtype(specs)

    def init(self, key: jax.Array) -> dict:
        return init_params(key, self.param_specs())

    def n_params(self) -> int:
        return param_count(self.param_specs())

    # ------------------------------------------------------------------
    # forward
    # ------------------------------------------------------------------
    def _block(self, p: dict, ctx: QuantContext, scope: str, sig: tuple,
               h: jax.Array, positions: jax.Array, *,
               window="cfg", cache: Optional[dict] = None,
               cache_pos=None, decode: bool = False,
               block_tables: Optional[jax.Array] = None,
               chunk_valid: Optional[jax.Array] = None,
               chunk_start: Optional[jax.Array] = None,
               chunk_ring: bool = False,
               layer_idx: Optional[int] = None,
               paged_attn: str = "fused"):
        cfg = self.cfg
        block, is_moe = sig
        new_cache = cache
        hn = L.apply_norm(p["attn_norm"], h, cfg.norm)
        aux = jnp.zeros((), jnp.float32)
        resume = None if chunk_start is None else chunk_start > 0
        # paged decode: a block-table row of -1 marks a vacant or mid-prefill
        # slot — its SSM state must pass through the step untouched, exactly
        # like its K/V writes go to the trash block
        row_valid = (block_tables[:, 0] >= 0
                     if decode and block_tables is not None else None)
        if block == "attn":
            y, new_cache = L.attention(p["attn"], ctx, f"{scope}/attn",
                                       cfg.attn_cfg_for(layer_idx), hn,
                                       positions,
                                       cache=cache, cache_pos=cache_pos,
                                       block_tables=block_tables,
                                       chunk_valid=chunk_valid,
                                       chunk_start=chunk_start,
                                       chunk_ring=chunk_ring,
                                       window=window, paged_attn=paged_attn)
        elif block == "mla":
            y, new_cache = L.mla_attention(p["attn"], ctx, f"{scope}/attn",
                                           cfg.mla_cfg_for(layer_idx), hn,
                                           positions,
                                           cache=cache, cache_pos=cache_pos,
                                           block_tables=block_tables,
                                           chunk_valid=chunk_valid,
                                           chunk_start=chunk_start,
                                           chunk_ring=chunk_ring,
                                           paged_attn=paged_attn)
        elif block == "mamba":
            if decode:
                y, new_cache = M.apply_mamba_decode(p["mamba"], ctx,
                                                    f"{scope}/mamba", cfg.ssm,
                                                    hn, cache,
                                                    row_valid=row_valid)
            else:
                y, new_cache = M.apply_mamba(p["mamba"], ctx, f"{scope}/mamba",
                                             cfg.ssm, hn, cache,
                                             chunk_valid=chunk_valid,
                                             resume=resume)
        elif block == "hybrid":
            a_cache = None if cache is None else cache.get("attn")
            m_cache = None if cache is None else cache.get("mamba")
            ya, a_new = L.attention(p["attn"], ctx, f"{scope}/attn",
                                    cfg.attn_cfg_for(layer_idx), hn,
                                    positions,
                                    cache=a_cache, cache_pos=cache_pos,
                                    block_tables=block_tables,
                                    chunk_valid=chunk_valid,
                                    chunk_start=chunk_start,
                                    chunk_ring=chunk_ring, window=window,
                                    paged_attn=paged_attn)
            if decode:
                ym, m_new = M.apply_mamba_decode(p["mamba"], ctx,
                                                 f"{scope}/mamba", cfg.ssm,
                                                 hn, m_cache,
                                                 row_valid=row_valid)
            else:
                ym, m_new = M.apply_mamba(p["mamba"], ctx, f"{scope}/mamba",
                                          cfg.ssm, hn, m_cache,
                                          chunk_valid=chunk_valid,
                                          resume=resume)
            y = 0.5 * (ya + ym)
            if cache is not None:
                new_cache = {"attn": a_new, "mamba": m_new}
        else:
            raise ValueError(block)
        h = h + y
        if is_moe:
            hn2 = L.apply_norm(p["mlp_norm"], h, cfg.norm)
            ym, aux = MOE.apply_moe(p["moe"], ctx, f"{scope}/moe", hn2,
                                    cfg.moe, cfg.activation)
            h = h + ym
        elif cfg.d_ff > 0:
            hn2 = L.apply_norm(p["mlp_norm"], h, cfg.norm)
            h = h + L.apply_mlp(p["mlp"], ctx, f"{scope}/mlp", hn2,
                                cfg.activation)
        return h, new_cache, aux

    def _backbone(self, params: dict, ctx: QuantContext, h: jax.Array,
                  positions: jax.Array, *, caches: Optional[dict] = None,
                  cache_pos=None, decode: bool = False,
                  block_tables: Optional[jax.Array] = None,
                  chunk_valid: Optional[jax.Array] = None,
                  chunk_start: Optional[jax.Array] = None,
                  chunk_ring: bool = False,
                  paged_attn: str = "fused"):
        """Run all layers. caches: {"layers/i" or "segments/s": cache pytree}."""
        from repro.distributed.sharding import shard_hint
        cfg = self.cfg
        # pin the residual stream to batch-sharding: without this, FSDP
        # weight shardings propagate into h (batch replicated, d_model
        # sharded) and the layer-scan residual stack inflates 16x
        h = shard_hint(h, ("pod", "data"), None, None)
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {} if caches is not None else None
        if cfg.scan_layers:
            for s, (sig, idxs) in enumerate(cfg.segments()):
                seg_params = params["segments"][str(s)]
                windows = jnp.array(
                    [w if (w := cfg.window_for(i)) is not None else BIG_WINDOW
                     for i in idxs], jnp.int32)
                seg_cache = None if caches is None else caches[f"segments/{s}"]

                def body(carry, xs):
                    h_, aux_ = carry
                    p_i, win_i, cache_i = xs
                    h_, c_new, aux_i = self._block(
                        p_i, ctx, f"segments/{s}", sig, h_, positions,
                        window=win_i, cache=cache_i, cache_pos=cache_pos,
                        decode=decode, block_tables=block_tables,
                        chunk_valid=chunk_valid, chunk_start=chunk_start,
                        chunk_ring=chunk_ring, paged_attn=paged_attn)
                    return (h_, aux_ + aux_i), c_new

                if cfg.remat:
                    body = jax.checkpoint(body)
                # NOTE: no sharding constraint inside the scan body — a wsc
                # in a scanned-over region makes partial-eval stack an f32
                # copy of the carry per layer (21GB at 32B scale). The entry
                # constraint + input batch constraints keep propagation sane.
                xs = (seg_params, windows, seg_cache)
                G = cfg.remat_group
                n_seg = len(idxs)
                main = (n_seg // G) * G if G > 1 else 0
                if cfg.remat and caches is None and main >= 2 * G:
                    # two-level remat scan: residual stacks shrink from O(L)
                    # to O(L/G + G) h-sized entries (sqrt-remat); a remainder
                    # of n_seg % G layers runs as a plain scan tail
                    xs_main = jax.tree.map(lambda a: a[:main], xs)
                    xs_tail = jax.tree.map(lambda a: a[main:], xs)
                    xs_g = jax.tree.map(
                        lambda a: a.reshape(main // G, G, *a.shape[1:]),
                        xs_main)

                    def group_body(carry, xs_i):
                        return jax.lax.scan(body, carry, xs_i)

                    (h, aux_total), seg_cache_new = jax.lax.scan(
                        jax.checkpoint(group_body), (h, aux_total), xs_g)
                    if main < n_seg:
                        (h, aux_total), _tail_cache = jax.lax.scan(
                            body, (h, aux_total), xs_tail)
                else:
                    (h, aux_total), seg_cache_new = jax.lax.scan(
                        body, (h, aux_total), xs)
                if new_caches is not None:
                    new_caches[f"segments/{s}"] = seg_cache_new
        else:
            for i in range(cfg.n_layers):
                sig = cfg.layer_signature(i)
                cache_i = None if caches is None else caches[f"layers/{i}"]

                def body(p_i, h_, cache_i_):
                    return self._block(p_i, ctx, f"layers/{i}", sig, h_,
                                       positions, window=cfg.window_for(i),
                                       cache=cache_i_, cache_pos=cache_pos,
                                       decode=decode,
                                       block_tables=block_tables,
                                       chunk_valid=chunk_valid,
                                       chunk_start=chunk_start,
                                       chunk_ring=chunk_ring, layer_idx=i,
                                       paged_attn=paged_attn)

                if cfg.remat:
                    body = jax.checkpoint(body)
                h, c_new, aux_i = body(params["layers"][str(i)], h, cache_i)
                aux_total = aux_total + aux_i
                if new_caches is not None:
                    new_caches[f"layers/{i}"] = c_new
        h = L.apply_norm(params["final_norm"], h, cfg.norm)
        return h, new_caches, aux_total

    def _embed(self, params: dict, tokens: jax.Array,
               prefix_embeds: Optional[jax.Array]) -> tuple:
        emb = jnp.take(params["embed"]["w"], tokens, axis=0).astype(self.dtype)
        if prefix_embeds is not None:
            emb = jnp.concatenate([prefix_embeds.astype(self.dtype), emb], axis=1)
        B, T = emb.shape[0], emb.shape[1]
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None],
                                     (B, T))
        return emb, positions

    def _head(self, params: dict, ctx: QuantContext, h: jax.Array) -> jax.Array:
        w = params["embed"]["w"] if self.cfg.tie_embeddings else params["lm_head"]["w"]
        from repro.quant import qops
        return qops.linear(ctx, "lm_head", h, w)

    def apply(self, params: dict, tokens: jax.Array, ctx: QuantContext, *,
              prefix_embeds: Optional[jax.Array] = None) -> jax.Array:
        """Full forward -> logits (B, T, V). For small models/tests."""
        h, positions = self._embed(params, tokens, prefix_embeds)
        h, _, _ = self._backbone(params, ctx, h, positions)
        return self._head(params, ctx, h)

    # ------------------------------------------------------------------
    # loss (chunked over sequence so (T, vocab) logits never materialize)
    # ------------------------------------------------------------------
    def loss(self, params: dict, batch: dict, ctx: QuantContext) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        labels = batch["labels"]
        weights = batch.get("weights")
        h, positions = self._embed(params, tokens, batch.get("prefix_embeds"))
        h, _, aux = self._backbone(params, ctx, h, positions)
        if batch.get("prefix_embeds") is not None:
            h = h[:, -tokens.shape[1]:]  # loss only over text positions
        from repro.nn.losses import chunked_ce_loss
        loss = chunked_ce_loss(lambda hi: self._head(params, ctx, hi), h,
                               labels, weights, cfg.loss_chunk,
                               no_scan=(ctx.mode == "probe"))
        if cfg.mtp_depth > 0:
            B, T, _ = h.shape
            if weights is None:
                weights = jnp.ones((B, T), jnp.float32)
            mtp_fn = self._mtp_loss
            if cfg.remat:
                mtp_fn = jax.checkpoint(mtp_fn, static_argnums=(1,))
            loss = loss + cfg.mtp_weight * mtp_fn(
                params, ctx, h, tokens, labels, weights)
        return loss + aux

    def _mtp_loss(self, params, ctx, h, tokens, labels, weights):
        """DeepSeek-V3 multi-token prediction: predict t+2 from (h_t, emb_{t+1})."""
        cfg = self.cfg
        emb_next = jnp.take(params["embed"]["w"], labels, axis=0).astype(self.dtype)
        hcat = jnp.concatenate([h, emb_next], axis=-1)
        from repro.quant import qops
        hm = qops.linear(ctx, "mtp/proj", hcat, params["mtp"]["proj"]["w"])
        hm = L.apply_norm(params["mtp"]["norm"], hm, cfg.norm)
        B, T = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        hm, _, _ = self._block(params["mtp"]["block"], ctx, "mtp/block",
                               cfg.layer_signature(cfg.n_layers - 1), hm,
                               positions)
        # targets: labels shifted by one more step
        tgt = jnp.pad(labels[:, 1:], ((0, 0), (0, 1)))
        w = jnp.pad(weights[:, 1:], ((0, 0), (0, 1)))
        from repro.nn.losses import chunked_ce_loss
        return chunked_ce_loss(lambda hi: self._head(params, ctx, hi), hm,
                               tgt, w, cfg.loss_chunk,
                               no_scan=(ctx.mode == "probe"))

    # ------------------------------------------------------------------
    # serving
    # ------------------------------------------------------------------
    @property
    def _kv_dtype(self):
        return (jnp.float8_e4m3fn if self.cfg.kv_cache_dtype == "fp8_e4m3"
                else self.dtype)

    def _assemble_cache_specs(self, one) -> dict:
        """Stitch per-layer cache specs (``one(sig) -> {sub: tree}``) into the
        flat ``layers/i@sub/path`` (or ``segments/s@...``) namespace."""
        cfg = self.cfg
        specs: dict = {}
        if cfg.scan_layers:
            for s, (sig, idxs) in enumerate(cfg.segments()):
                for sub, tree in one(sig).items():
                    for path, ps in flatten_paths(tree).items():
                        specs[f"segments/{s}@{sub}/{path}"] = ParamSpec(
                            (len(idxs),) + ps.shape,
                            ("layers",) + ps.logical_axes, ps.dtype, "zeros")
        else:
            for i in range(cfg.n_layers):
                for sub, tree in one(cfg.layer_signature(i)).items():
                    for path, ps in flatten_paths(tree).items():
                        specs[f"layers/{i}@{sub}/{path}"] = ps
        return specs

    def cache_specs(self, batch: int, max_len: int,
                    ring_window: bool = True, chunk_extra: int = 0) -> dict:
        """Flat path->ParamSpec dict for the dense KV/SSM caches.
        ``ring_window=False`` keeps full ``max_len`` K/V rows for
        sliding-window layers (window enforced by mask only) — required for
        a prefill cache that will be reshaped into paged blocks.
        ``chunk_extra`` widens windowed rings to ``window + chunk_extra``
        rows so dense chunked prefill never evicts in-window keys (engines
        pass their ``chunk_len``; see :func:`repro.nn.layers.kv_cache_spec`)."""
        cfg = self.cfg
        kv_dtype = self._kv_dtype

        def one(sig) -> dict:
            block, _ = sig
            if block == "attn":
                return {"attn": L.kv_cache_spec(cfg.attn_cfg, batch, max_len,
                                                kv_dtype, ring=ring_window,
                                                chunk_extra=chunk_extra)}
            if block == "mla":
                return {"attn": L.mla_cache_spec(cfg.mla_cfg, batch, max_len,
                                                 kv_dtype)}
            if block == "mamba":
                return {"mamba": M.mamba_cache_spec(cfg.ssm, batch, self.dtype)}
            if block == "hybrid":
                return {"attn": L.kv_cache_spec(cfg.attn_cfg, batch, max_len,
                                                kv_dtype, ring=ring_window,
                                                chunk_extra=chunk_extra),
                        "mamba": M.mamba_cache_spec(cfg.ssm, batch, self.dtype)}
            raise ValueError(block)

        return self._assemble_cache_specs(one)

    def paged_cache_specs(self, n_slots: int, n_blocks: int,
                          block_size: int) -> dict:
        """Flat specs for paged serving: attention K/V (and MLA latents) are
        block-major ``(n_blocks, block_size, ...)`` shared storage; SSM state
        has no sequence axis and stays slot-major ``(n_slots, ...)``."""
        cfg = self.cfg
        kv_dtype = self._kv_dtype

        def one(sig) -> dict:
            block, _ = sig
            if block == "attn":
                return {"attn": L.kv_page_spec(cfg.attn_cfg, n_blocks,
                                               block_size, kv_dtype)}
            if block == "mla":
                return {"attn": L.mla_page_spec(cfg.mla_cfg, n_blocks,
                                                block_size, kv_dtype)}
            if block == "mamba":
                return {"mamba": M.mamba_cache_spec(cfg.ssm, n_slots,
                                                    self.dtype)}
            if block == "hybrid":
                return {"attn": L.kv_page_spec(cfg.attn_cfg, n_blocks,
                                               block_size, kv_dtype),
                        "mamba": M.mamba_cache_spec(cfg.ssm, n_slots,
                                                    self.dtype)}
            raise ValueError(block)

        return self._assemble_cache_specs(one)

    @staticmethod
    def _cache_tree(flat_specs_or_vals: dict) -> dict:
        """'layers/0@attn/k' flat keys -> {"layers/0": {"attn": {"k": ...}}}."""
        out: dict = {}
        for key, v in flat_specs_or_vals.items():
            head, rest = key.split("@", 1)
            sub = rest.split("/")
            node = out.setdefault(head, {})
            for spart in sub[:-1]:
                node = node.setdefault(spart, {})
            node[sub[-1]] = v
        return out

    def init_cache(self, batch: int, max_len: int, abstract: bool = False,
                   ring_window: bool = True, chunk_extra: int = 0) -> dict:
        return self._materialize_cache(
            self.cache_specs(batch, max_len, ring_window=ring_window,
                             chunk_extra=chunk_extra),
            abstract)

    def init_paged_cache(self, n_slots: int, n_blocks: int, block_size: int,
                         abstract: bool = False) -> dict:
        return self._materialize_cache(
            self.paged_cache_specs(n_slots, n_blocks, block_size), abstract)

    @classmethod
    def assemble_cache_tree(cls, flat: dict) -> dict:
        """Flat ``layers/i@sub/path`` keys -> the nested cache pytree the
        engines carry (same structure for any leaf values — specs, arrays,
        or shardings, so a sharding tree built from cache *specs* always
        ``tree.map``s against the materialized cache)."""
        tree = cls._cache_tree(flat)
        # unwrap single-sub caches: {"attn": {...}} -> cache dict for _block
        out = {}
        for lk, subs in tree.items():
            if set(subs) == {"attn"}:
                out[lk] = subs["attn"]
            elif set(subs) == {"mamba"}:
                out[lk] = subs["mamba"]
            else:
                out[lk] = subs
        return out

    def _materialize_cache(self, specs: dict, abstract: bool = False) -> dict:
        if abstract:
            flat = {k: jax.ShapeDtypeStruct(s.shape, s.dtype)
                    for k, s in specs.items()}
        else:
            flat = {}
            for k, s in specs.items():
                if k.endswith("/pos"):
                    flat[k] = jnp.full(s.shape, -1, jnp.int32)
                else:
                    flat[k] = jnp.zeros(s.shape, s.dtype)
        return self.assemble_cache_tree(flat)

    def paged_insert(self, paged: dict, dense1: dict, block_ids: jax.Array,
                     slot: jax.Array) -> dict:
        """Scatter a freshly prefilled batch=1 dense cache into paged storage.

        Page-major leaves (attention K/V, MLA latents) land in the physical
        blocks named by ``block_ids``; the dense prefill length must equal
        ``len(block_ids) * block_size`` so the reshape is exact. Slot-major
        leaves (SSM state) overwrite row ``slot``. The dense ``pos`` ring is
        dropped: paged attention derives key positions from block-table
        order. Pure function of its array args — jit it once per distinct
        prompt-block count.
        """
        scan = self.cfg.scan_layers
        nb = block_ids.shape[0]
        slot = jnp.asarray(slot, jnp.int32)

        def rec(pv, dv):
            if isinstance(pv, dict):
                if "pos" in dv and "pos" not in pv:    # attention page node
                    out = {}
                    for name, leaf in pv.items():
                        src = dv[name]
                        if scan:
                            n_l, bs = leaf.shape[0], leaf.shape[2]
                            s = src[:, 0].reshape((n_l, nb, bs) + src.shape[3:])
                            out[name] = leaf.at[:, block_ids].set(
                                s.astype(leaf.dtype))
                        else:
                            bs = leaf.shape[1]
                            s = src[0].reshape((nb, bs) + src.shape[2:])
                            out[name] = leaf.at[block_ids].set(
                                s.astype(leaf.dtype))
                    return out
                return {k: rec(v, dv[k]) for k, v in pv.items()}
            # slot-major leaf (SSM state): overwrite row ``slot``
            axis = 1 if scan else 0
            start = (0,) * axis + (slot,) + (0,) * (pv.ndim - axis - 1)
            return jax.lax.dynamic_update_slice(pv, dv.astype(pv.dtype), start)

        return {k: rec(v, dense1[k]) for k, v in paged.items()}

    def paged_copy_block(self, paged: dict, src: jax.Array,
                         dst: jax.Array) -> dict:
        """Copy physical block ``src`` into block ``dst`` on every page-major
        cache leaf (attention K/V, MLA latents); slot-major leaves (SSM
        state) pass through untouched.

        This is the pool's copy-on-write fork: a slot about to write into a
        block it shares with other slots first duplicates the block and
        repoints its table entry at the copy, so the parent chain other
        requests attend is never mutated. Pure function of its array args —
        the pool jits it once per (model, layout) and traces over the
        src/dst block ids."""
        axes = self.assemble_cache_tree({
            k: (s.logical_axes.index("kv_blocks")
                if "kv_blocks" in s.logical_axes else -1)
            for k, s in self.paged_cache_specs(1, 1, 1).items()})
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)

        def cp(leaf, ax):
            if ax < 0:
                return leaf
            blk = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=ax)
            return jax.lax.dynamic_update_slice_in_dim(leaf, blk, dst, ax)

        return jax.tree.map(cp, paged, axes)

    def prefill(self, params: dict, tokens: jax.Array, caches: dict,
                ctx: QuantContext, *,
                prefix_embeds: Optional[jax.Array] = None):
        """Process the prompt; returns (last-token logits, caches)."""
        h, positions = self._embed(params, tokens, prefix_embeds)
        h, caches, _ = self._backbone(params, ctx, h, positions, caches=caches)
        logits = self._head(params, ctx, h[:, -1:])
        return logits, caches

    def prefill_chunk(self, params: dict, tokens: jax.Array, caches: dict,
                      ctx: QuantContext, *, start_pos: jax.Array,
                      valid_len: jax.Array,
                      block_tables: Optional[jax.Array] = None,
                      chunk_ring: bool = False):
        """Process one (possibly padded) prompt chunk for every cache row.

        The batched/bucketed twin of :meth:`prefill`: every row of
        ``tokens`` (B, Lb) is padded to a shared bucket length, so engines
        compile one program per bucket instead of one per distinct prompt
        length, and B matches the decode batch so the step is shape-stable.

        * ``start_pos`` (B,): absolute position of ``tokens[:, 0]``. 0 marks
          a first chunk — it resets the row's ring ``pos`` entries (dense)
          and SSM state, so slot reuse cannot leak the previous occupant.
          Engines pass a nonzero start for vacant/decoding rows.
        * ``valid_len`` (B,): real token count per row; 0 = inactive row
          (its caches/state pass through bit-unchanged, its writes go to the
          trash block / are dropped).
        * ``block_tables`` (B, max_blocks): paged mode — the chunk's K/V is
          written straight into physical blocks ("paged prefill") and
          attention runs over the gathered logical layout, so prompts longer
          than a chunk resume exactly where the previous chunk stopped.
          None = dense bucketed single-shot prefill into the row's ring.
        * ``chunk_ring``: dense continuation mode — attend the whole ring
          gathered into logical order instead of only the chunk's local K/V,
          so dense engines can split prompts into chunks too. Windowed archs
          need rings widened by ``chunk_len`` (``init_cache(chunk_extra=)``).

        Returns (logits (B, 1, V) at each row's last valid position, caches).
        """
        B, T = tokens.shape
        start = jnp.asarray(start_pos, jnp.int32)
        valid = jnp.asarray(valid_len, jnp.int32)
        emb = jnp.take(params["embed"]["w"], tokens, axis=0).astype(self.dtype)
        positions = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
        chunk_valid = jnp.arange(T, dtype=jnp.int32)[None] < valid[:, None]
        h, caches, _ = self._backbone(params, ctx, emb, positions,
                                      caches=caches, chunk_valid=chunk_valid,
                                      chunk_start=start,
                                      chunk_ring=chunk_ring,
                                      block_tables=block_tables)
        idx = jnp.maximum(valid - 1, 0)          # inactive rows: garbage out
        h_last = jnp.take_along_axis(h, idx[:, None, None], axis=1)
        logits = self._head(params, ctx, h_last)
        return logits, caches

    def decode_step(self, params: dict, token: jax.Array, pos: jax.Array,
                    caches: dict, ctx: QuantContext, *,
                    block_tables: Optional[jax.Array] = None,
                    paged_attn: str = "fused"):
        """One token for every sequence. token: (B,1); pos: scalar int32 for
        a lock-step batch, or (B,) int32 with one position per sequence
        (continuous batching: every cache slot decodes at its own depth).
        ``block_tables`` (B, max_blocks) switches attention caches to the
        paged layout (shared across layers; SSM state stays slot-major);
        each row's per-row length is its position + 1, which the default
        fused paged-attention kernel masks against — ``paged_attn="gather"``
        selects the reference gather-then-attend path instead."""
        emb = jnp.take(params["embed"]["w"], token, axis=0).astype(self.dtype)
        B = token.shape[0]
        pos = jnp.asarray(pos, jnp.int32)
        if pos.ndim == 1:
            positions = pos[:, None]
        else:
            positions = jnp.broadcast_to(pos[None, None], (B, 1))
        h, caches, _ = self._backbone(params, ctx, emb, positions,
                                      caches=caches, cache_pos=pos,
                                      decode=True, block_tables=block_tables,
                                      paged_attn=paged_attn)
        logits = self._head(params, ctx, h)
        return logits, caches

    # ------------------------------------------------------------------
    # abstract views
    # ------------------------------------------------------------------
    def abstract_params(self, shardings: Optional[dict] = None) -> dict:
        return abstract_params(self.param_specs(), shardings)
