"""Architecture registry: ``--arch <id>`` -> model builder.

Each assigned architecture lives in ``repro/configs/<id>.py`` exposing
``config(**overrides)`` (full-size, exact published dims) and
``smoke_config()`` (same family, reduced dims for CPU tests).
"""
from __future__ import annotations

import importlib
from typing import Any

ARCH_IDS = [
    "hymba_1p5b",
    "nemotron_4_15b",
    "qwen2p5_3b",
    "qwen2p5_32b",
    "starcoder2_15b",
    "moonshot_v1_16b_a3b",
    "deepseek_v3_671b",
    "whisper_base",
    "llava_next_34b",
    "mamba2_370m",
    # the paper's own models (reduced-scale stand-ins train on CPU)
    "llama3_1b",
    "llama3_8b",
]

# external-id aliases (the assignment list uses dashed names)
ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen2.5-3b": "qwen2p5_3b",
    "qwen2.5-32b": "qwen2p5_32b",
    "starcoder2-15b": "starcoder2_15b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-base": "whisper_base",
    "llava-next-34b": "llava_next_34b",
    "mamba2-370m": "mamba2_370m",
}


def canonical(arch: str) -> str:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return arch


def _module(arch: str):
    return importlib.import_module(f"repro.configs.{canonical(arch)}")


def get_config(arch: str, **overrides) -> Any:
    return _module(arch).config(**overrides)


def get_smoke_config(arch: str, **overrides) -> Any:
    return _module(arch).smoke_config(**overrides)


def build_model(cfg) -> Any:
    from repro.models.encdec import EncDec, EncDecConfig
    from repro.models.lm import LM, LMConfig
    if isinstance(cfg, EncDecConfig):
        return EncDec(cfg)
    if isinstance(cfg, LMConfig):
        return LM(cfg)
    raise TypeError(type(cfg))


def get_model(arch: str, smoke: bool = False, **overrides):
    cfg = get_smoke_config(arch, **overrides) if smoke else get_config(arch, **overrides)
    return build_model(cfg)
