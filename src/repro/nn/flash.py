"""Blocked (flash-style) attention in pure JAX.

Used for long sequences (prefill_32k / train_4k) where materializing the
(T x T) score matrix would blow HBM. Numerically equivalent to the reference
path (running max / running denominator), O(T * block) memory.

MP integration: the paper quantizes ``qk_matmul`` and ``av_matmul``. Here Q/K
are quantized once up front (identical numerics to quantizing per block with
per-tensor scales) and the block-local probabilities are quantized inside the
loop for ``av_matmul``. Probe/capture calibration uses the reference path —
calibration batches are short (see DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import qtensor
from repro.quant.formats import get_format
from repro.quant.qops import OpInfo, QuantContext, act_quant_axes

__all__ = ["flash_attention"]


def _register(ctx: QuantContext, scope: str, q, k, v):
    if ctx.registry is None:
        return
    B, T, H, D = q.shape
    S = k.shape[1]
    ctx.registry.append(OpInfo(
        name=f"{scope}/qk_matmul", kind="bgemm", spec="BTHD,BSHD->BHTS",
        lhs_shape=(B, T, H, D), rhs_shape=tuple(k.shape),
        out_shape=(B, H, T, S), macs=B * H * T * S * D, weight_elems=0))
    ctx.registry.append(OpInfo(
        name=f"{scope}/av_matmul", kind="bgemm", spec="BHTS,BSHD->BTHD",
        lhs_shape=(B, H, T, S), rhs_shape=tuple(v.shape),
        out_shape=(B, T, H, D), macs=B * H * T * S * v.shape[-1],
        weight_elems=0))


def _mp_fmt(ctx: QuantContext, name: str) -> Optional[str]:
    if ctx.mode != "mp":
        return None
    f = ctx.format_for(name)
    return f if get_format(f).is_quantized else None


def flash_attention(ctx: QuantContext, scope: str, q: jax.Array, k: jax.Array,
                    v: jax.Array, positions: jax.Array, *, causal: bool,
                    window: Optional[int], block: int = 1024) -> jax.Array:
    """q: (B,T,H,Dk), k: (B,S,Hkv,Dk), v: (B,S,Hkv,Dv) -> (B,T,H,Dv).

    Assumes self-attention with q/k positions equal to ``positions`` and
    T == S (prefill / training). GQA handled by head-group reshape.
    """
    B, T, H, Dk = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // Hkv
    _register(ctx, scope, q, k, v)

    qk_fmt = _mp_fmt(ctx, f"{scope}/qk_matmul")
    av_fmt = _mp_fmt(ctx, f"{scope}/av_matmul")
    # q/k/v are activations: honor per-sequence / per-token scales (serving
    # contexts). Token-granular: (B, T, H, D) keeps (B, T), reduces (H, D) —
    # the same slices qeinsum derives for the reference path's qk operands.
    axes = (2, 3) if ctx.act_scale_token else act_quant_axes(ctx, 4)
    if qk_fmt is not None:
        q = qtensor.fake_quant(q, qk_fmt, axis=axes)
        k = qtensor.fake_quant(k, qk_fmt, axis=axes)
    if av_fmt is not None:
        v = qtensor.fake_quant(v, av_fmt, axis=axes)

    nq = -(-T // block)
    nk = -(-S // block)
    pad_q = nq * block - T
    pad_k = nk * block - S
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad_q)),
                            constant_values=jnp.iinfo(jnp.int32).max)
    if causal or window is not None:
        assert S <= positions.shape[1], "masked flash requires kv positions"
        kpos = positions[:, :S]
    else:  # unmasked (cross-attention): positions unused
        kpos = jnp.zeros((B, S), jnp.int32)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad_k)),
                       constant_values=jnp.iinfo(jnp.int32).min)

    scale = 1.0 / math.sqrt(Dk)
    # (B, nq, blk, Hkv, G, Dk)
    qb = q.reshape(B, nq, block, Hkv, G, Dk)
    kb = k.reshape(B, nk, block, Hkv, Dk)
    vb = v.reshape(B, nk, block, Hkv, Dv)
    qpb = positions.reshape(B, nq, block)
    kpb = kpos.reshape(B, nk, block)

    def q_block(qi):
        qq = qb[:, qi]            # (B, blk, Hkv, G, Dk)
        qp = qpb[:, qi]           # (B, blk)

        def kv_step(carry, kj):
            m, l, acc = carry
            kk = kb[:, kj]
            vv = vb[:, kj]
            kp = kpb[:, kj]
            s = jnp.einsum("BTKGD,BSKD->BKGTS", qq, kk,
                           preferred_element_type=jnp.float32) * scale
            allow = jnp.ones((B, block, block), bool)
            if causal:
                allow &= kp[:, None, :] <= qp[:, :, None]
            if window is not None:
                allow &= kp[:, None, :] > (qp[:, :, None] - window)
            s = jnp.where(allow[:, None, None, :, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + jnp.sum(p, axis=-1)
            pq = p.astype(vv.dtype)
            if av_fmt is not None:
                # per-sequence/per-token scales here too, else co-batched
                # rows couple through the block-probability amax. pq is
                # (B, Hkv, G, blk_q, blk_k): token-granular keeps (B, blk_q)
                pq = qtensor.fake_quant(
                    pq, av_fmt,
                    axis=((1, 2, 4) if ctx.act_scale_token
                          else act_quant_axes(ctx, pq.ndim)))
            pv = jnp.einsum("BKGTS,BSKD->BKGTD", pq, vv,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, block), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, block, Dv), jnp.float32)
        # causal: only blocks kj <= qi contribute; scan all for static shape,
        # masking handles correctness (XLA still does the work — acceptable
        # for clarity; the Pallas kernel path skips masked blocks).
        # checkpoint: block scores/probs are recomputed in the backward pass
        # instead of being stashed as scan residuals (O(T^2) -> O(T) memory).
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      jnp.arange(nk))
        l = jnp.maximum(l, 1e-20)
        out = acc / l[..., None]
        return out  # (B, Hkv, G, blk, Dv)

    outs = jax.lax.map(q_block, jnp.arange(nq))  # (nq, B, Hkv, G, blk, Dv)
    outs = jnp.moveaxis(outs, 0, 1)              # (B, nq, Hkv, G, blk, Dv)
    outs = jnp.transpose(outs, (0, 1, 4, 2, 3, 5)).reshape(
        B, nq * block, Hkv * G, Dv)
    return outs[:, :T].astype(v.dtype)
