"""Functional NN layers (norms, rope, MLP, attention) with spec builders.

Conventions:
* params are nested dicts; spec builders return flat ``path -> ParamSpec``.
* every quantizable matmul goes through ``repro.quant.qops`` with an op name
  equal to its param-path prefix (e.g. ``layers/3/attn/q_proj``), so the MP
  pipeline, the partitioner and the param tree share one namespace.
* weights are stored (out_features, in_features) following eq. (8) of the
  paper: ``y = x @ w^T + b``.

KV cache — two layouts share the attention math:

* dense ring (the default / one-shot path): ``{"k": (B,W,Hkv,D), "v": ...,
  "pos": (B,W)}`` where ``pos`` holds the absolute position stored in each
  slot (-1 = empty). ``W = min(max_len, window)`` — sliding-window archs get
  O(window) decode memory (what makes hymba ``long_500k`` deployable);
  full-attention archs use W = max_len where the ring write degenerates to
  an append.
* paged blocks (continuous serving): ``{"k": (n_blocks, block_size, Hkv,
  D), "v": ...}`` — physical blocks owned by a ``PagedCachePool``; each
  decode row carries a block table (row of physical block ids, -1 =
  unallocated) and logical position ``j*block_size + i`` lives at page-table
  entry ``j``, offset ``i``. There is no ``pos`` leaf: the pool guarantees
  every block a row's table maps is written contiguously up to the row's
  position, so every key at logical position <= the query position is fresh
  by construction and the causal mask alone separates live keys from stale
  block contents. Writes stay single-owner: a block referenced by several
  tables (prefix caching) is read-shared only — the pool copy-on-write
  forks it before any chunk would write into it, and decode never writes a
  shared page (its write range starts past the matched prefix). Block 0
  is a trash block (never allocated) that absorbs writes from vacant decode
  rows, whose block tables are all -1. Blocks are written one token per
  decode step (``paged_write``) or a whole prefill chunk at a time
  (``paged_write_chunk`` — the "paged prefill" path, which also routes
  bucket-padding writes to the trash block).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.nn.spec import ParamSpec
from repro.quant import qops
from repro.quant.qops import QuantContext

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_specs(prefix: str, dim: int, kind: str = "rmsnorm") -> dict:
    specs = {f"{prefix}/scale": ParamSpec((dim,), ("embed",), jnp.float32, "ones")}
    if kind == "layernorm":
        specs[f"{prefix}/bias"] = ParamSpec((dim,), ("embed",), jnp.float32, "zeros")
    return specs


def apply_norm(p: dict, x: jax.Array, kind: str = "rmsnorm",
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    elif kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        raise ValueError(kind)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_table(positions: jax.Array, d_head: int, theta: float) -> tuple:
    """positions: (..., T) int32 -> (sin, cos) of shape (..., T, d_head//2)."""
    half = d_head // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, T, H, D); sin/cos: (B, T, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :].astype(jnp.float32)
    c = cos[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":  # squared ReLU (Nemotron-4 / Primer)
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def mlp_specs(prefix: str, d_model: int, d_ff: int, activation: str,
              bias: bool = False) -> dict:
    specs = {}
    if activation == "swiglu":
        specs[f"{prefix}/gate_proj/w"] = ParamSpec((d_ff, d_model), ("ffn", "embed"),
                                                   init="scaled_normal")
    specs[f"{prefix}/up_proj/w"] = ParamSpec((d_ff, d_model), ("ffn", "embed"),
                                             init="scaled_normal")
    specs[f"{prefix}/down_proj/w"] = ParamSpec((d_model, d_ff), ("embed", "ffn"),
                                               init="scaled_normal")
    if bias:
        specs[f"{prefix}/up_proj/b"] = ParamSpec((d_ff,), ("ffn",), init="zeros")
        specs[f"{prefix}/down_proj/b"] = ParamSpec((d_model,), ("embed",), init="zeros")
    return specs


def apply_mlp(p: dict, ctx: QuantContext, scope: str, x: jax.Array,
              activation: str) -> jax.Array:
    if activation == "swiglu":
        g = qops.linear(ctx, f"{scope}/gate_proj", x, p["gate_proj"]["w"])
        u = qops.linear(ctx, f"{scope}/up_proj", x, p["up_proj"]["w"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = qops.linear(ctx, f"{scope}/up_proj", x, p["up_proj"]["w"],
                        p["up_proj"].get("b"))
        h = _act(activation, u.astype(jnp.float32)).astype(x.dtype)
    return qops.linear(ctx, f"{scope}/down_proj", h, p["down_proj"]["w"],
                       p["down_proj"].get("b"))


# ---------------------------------------------------------------------------
# Attention (GQA, optional bias / sliding window / cross-attention)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qkv_bias: bool = False
    causal: bool = True
    rope_theta: Optional[float] = 10000.0   # None => NoPE (e.g. cross-attn)
    window: Optional[int] = None            # sliding-window size
    flash_min_seq: int = 4096               # blocked attention above this q_len
    flash_block: int = 1024
    # per-tensor dequant multipliers for *paged* KV-cache reads (e.g. an fp8
    # cache carrying a calibration scale): ((cache_entry, scale), ...) pairs
    # — a tuple, not a dict, so the frozen config stays hashable. One source
    # of truth for both paged read paths: the fused kernel dequantizes
    # in-register and the gather fallback applies the identical
    # f32-multiply-then-cast, so greedy tokens cannot depend on which path a
    # layer takes. Unit scales cost nothing on either path.
    kv_dequant_scales: Optional[tuple] = None


def attn_specs(prefix: str, cfg: AttnConfig) -> dict:
    dm, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    specs = {
        f"{prefix}/q_proj/w": ParamSpec((H * D, dm), ("heads", "embed"),
                                        init="scaled_normal"),
        f"{prefix}/k_proj/w": ParamSpec((Hkv * D, dm), ("heads", "embed"),
                                        init="scaled_normal"),
        f"{prefix}/v_proj/w": ParamSpec((Hkv * D, dm), ("heads", "embed"),
                                        init="scaled_normal"),
        f"{prefix}/o_proj/w": ParamSpec((dm, H * D), ("embed", "heads"),
                                        init="scaled_normal"),
    }
    if cfg.qkv_bias:
        for n, width in (("q_proj", H * D), ("k_proj", Hkv * D), ("v_proj", Hkv * D)):
            specs[f"{prefix}/{n}/b"] = ParamSpec((width,), ("heads",), init="zeros")
    return specs


def kv_cache_spec(cfg: AttnConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16, ring: bool = True,
                  chunk_extra: int = 0) -> dict:
    """``ring=False`` disables the sliding-window ring clamp and keeps the
    full ``max_len`` layout (positions stay contiguous from slot 0) — the
    shape ``LM.paged_insert`` needs to reshape a prefill cache into blocks;
    the window is still enforced by the attention mask.

    ``chunk_extra`` widens windowed rings to ``window + chunk_extra`` rows:
    a chunked prefill writes up to a whole chunk past the window before the
    chunk's earliest query attends, so a ring clamped exactly at ``window``
    would overwrite keys still inside that query's window whenever
    ``window`` is not chunk-aligned. Engines serving dense chunked prefill
    pass their ``chunk_len`` here; decode and one-shot prefill need no
    slack."""
    W = (max_len if (cfg.window is None or not ring)
         else min(max_len, cfg.window + chunk_extra))
    # kv_heads shard over 'model' when divisible; otherwise head_dim picks up
    # the model axis (contraction-dim sharding -> small score all-reduce)
    return {
        "k": ParamSpec((batch, W, cfg.n_kv_heads, cfg.d_head),
                       ("act_batch", None, "kv_heads", "head_dim"), dtype,
                       "zeros"),
        "v": ParamSpec((batch, W, cfg.n_kv_heads, cfg.d_head),
                       ("act_batch", None, "kv_heads", "head_dim"), dtype,
                       "zeros"),
        "pos": ParamSpec((batch, W), ("act_batch", None), jnp.int32, "zeros"),
    }


def kv_page_spec(cfg: AttnConfig, n_blocks: int, block_size: int,
                 dtype=jnp.bfloat16) -> dict:
    """Paged KV storage: ``n_blocks`` physical blocks of ``block_size``
    tokens, shared by all decode rows via block tables. Sliding-window archs
    keep masked-window *compute* but not O(window) *memory* under paging
    (block tables grow with absolute position).

    The leading block dim carries the ``kv_blocks`` logical axis: under a
    serving mesh the physical pool is device-sharded over ``data`` (each
    shard owns a contiguous page range, see ``PagedCachePool``), falling
    back to replication when ``n_blocks`` doesn't divide."""
    return {
        "k": ParamSpec((n_blocks, block_size, cfg.n_kv_heads, cfg.d_head),
                       ("kv_blocks", None, "kv_heads", "head_dim"), dtype,
                       "zeros"),
        "v": ParamSpec((n_blocks, block_size, cfg.n_kv_heads, cfg.d_head),
                       ("kv_blocks", None, "kv_heads", "head_dim"), dtype,
                       "zeros"),
    }


def paged_write_chunk(cache: dict, tensors: dict, block_tables: jax.Array,
                      positions: jax.Array, valid: jax.Array) -> dict:
    """Scatter a whole prefill chunk into each row's physical blocks (the
    "paged prefill" path: blocks are written directly, no dense-then-scatter).

    ``positions``: (B, T) absolute positions of the chunk's tokens;
    ``valid``: (B, T) bool — padded tail entries and vacant rows are routed
    to the trash block 0, as are positions whose page is unallocated (-1).
    Valid entries land at unique (page, offset) pairs because the pool
    keeps every written block single-writer (shared prefix pages are
    copy-on-write forked before they enter any write range) and writes
    contiguously. Chunks may start mid-sequence against a pre-populated
    table — resumed prefills and prefix-cache tail chunks rely on this.
    """
    bs = next(iter(cache.values())).shape[1]
    nb = block_tables.shape[1]
    page_idx = jnp.clip(positions // bs, 0, nb - 1)
    page = jnp.take_along_axis(block_tables, page_idx, axis=1)     # (B, T)
    page = jnp.maximum(jnp.where(valid, page, -1), 0)
    off = positions % bs
    new = dict(cache)
    for name, t in tensors.items():
        new[name] = cache[name].at[page, off].set(t.astype(cache[name].dtype))
    return new


def paged_write(cache: dict, tensors: dict, block_tables: jax.Array,
                cache_pos: jax.Array) -> dict:
    """Scatter one new token per decode row into its physical block.

    ``block_tables``: (B, max_blocks) int32 physical block ids; ``cache_pos``:
    (B,) absolute write positions. Rows with an unallocated page (table entry
    -1, e.g. vacant slots) are clamped to the trash block 0.
    """
    bs = next(iter(cache.values())).shape[1]
    B = block_tables.shape[0]
    cp = jnp.broadcast_to(jnp.asarray(cache_pos, jnp.int32), (B,))
    page = jnp.take_along_axis(block_tables, (cp // bs)[:, None], axis=1)[:, 0]
    page = jnp.maximum(page, 0)              # -1 (vacant/unallocated) -> trash
    off = cp % bs
    new = dict(cache)
    for name, t in tensors.items():
        new[name] = cache[name].at[page, off].set(t[:, 0].astype(cache[name].dtype))
    return new


def paged_gather(cache: dict, block_tables: jax.Array, dtype,
                 scales: Optional[dict] = None) -> tuple:
    """Gather each row's blocks into logical order: (B, S, ...) tensors plus
    the (B, S) logical key positions (S = max_blocks * block_size). Entries
    beyond a row's written length read stale/trash data; they sit at logical
    positions > the row's query position, so the causal mask removes them.

    ``scales`` maps cache-entry names to per-tensor dequant multipliers,
    applied with exactly the fused kernel's ``_dequant`` semantics (f32
    multiply, cast to ``dtype``; a 1.0 scale is a plain upcast so the
    unscaled path stays bit-identical to the legacy gather)."""
    bs = next(iter(cache.values())).shape[1]
    B, nb = block_tables.shape
    bt = jnp.maximum(block_tables, 0)

    def deq(name, arr):
        g = jnp.take(arr, bt, axis=0).reshape(B, nb * bs, *arr.shape[2:])
        s = 1.0 if scales is None else float(scales.get(name, 1.0))
        if s == 1.0:
            return g.astype(dtype)
        return (g.astype(jnp.float32) * s).astype(dtype)

    out = {name: deq(name, arr) for name, arr in cache.items()}
    kp = jnp.broadcast_to(jnp.arange(nb * bs, dtype=jnp.int32)[None], (B, nb * bs))
    return out, kp


def use_fused_paged(ctx: QuantContext, scope: str, paged_attn: str) -> bool:
    """THE paged-decode kernel switch: every call site (attention and MLA)
    funnels through this one predicate, so gather-vs-fused policy lives in
    exactly one place.

    The fused kernel replaces the reference path's two quantizable BGEMMs
    (``qk_matmul`` / ``av_matmul``) with in-kernel math, so it only serves
    layers where those ops run at full precision; a layer whose attention
    BGEMMs carry an MP format keeps the gather path and its exact
    quantization semantics. Probe mode and op-inventory traces also need the
    ``qops`` entry points (probe injection / OpInfo registration), so they
    stay on the reference path too.
    """
    assert paged_attn in ("fused", "gather"), paged_attn
    if paged_attn != "fused":
        return False
    if ctx.mode == "probe" or ctx.registry is not None:
        return False
    if ctx.mode == "mp":
        from repro.quant.formats import get_format
        for op in ("qk_matmul", "av_matmul"):
            if get_format(ctx.format_for(f"{scope}/{op}")).is_quantized:
                return False
    return True


def _mesh_fused_ok(batch: int, n_kv_heads: int) -> bool:
    """Mesh leg of the fused-paged dispatch: under a serving mesh the kernel
    runs per-shard (shard_map), which needs the decode batch to divide the
    ``data`` axis and the KV heads to divide ``model``; otherwise the layer
    takes the gather path, which GSPMD partitions correctly (and which is
    bit-identical to the kernel, so greedy parity holds either way)."""
    from repro.distributed.sharding import current_serving_layout
    layout = current_serving_layout()
    return layout is None or layout.fused_ok(batch, n_kv_heads)


def _paged_kernel_call(qk: jax.Array, k_pages: jax.Array, v_pages,
                       block_tables: jax.Array, lengths: jax.Array, *,
                       window=None, q2=None, k2=None, **kw) -> jax.Array:
    """Invoke the Pallas paged-decode kernel — per-shard under ``shard_map``
    when a serving mesh layout is active.

    Per shard the operands are: decode rows split over ``data`` (each shard
    sees its own slots' queries/lengths/block-table rows), KV heads split
    over ``model``, and — when the pool is page-sharded — the block dim
    split over ``data`` with global block ids translated to shard-local ones
    (slot ``s``'s blocks live in ``s``'s shard by pool construction; -1
    stays -1 and clamps to the shard's own trash block). Each per-shard grid
    keeps exactly the single-device kernel's per-row summation order, so
    sharded decode is bit-identical to the single-device engine."""
    from repro.kernels.paged_attention import paged_decode_attention
    from repro.distributed.sharding import current_serving_layout
    layout = current_serving_layout()
    if layout is None or (layout.data == 1 and layout.model == 1):
        return paged_decode_attention(qk, k_pages, v_pages, block_tables,
                                      lengths, window=window, q2=q2, k2=k2,
                                      **kw)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    q_spec = P("data", "model", None, None)
    page_spec = P("data" if layout.shard_pages else None, None, "model", None)
    operands = [qk, k_pages, block_tables, lengths]
    specs = [q_spec, page_spec, P("data", None), P("data")]
    has_v = v_pages is not None
    if has_v:
        operands.append(v_pages)
        specs.append(page_spec)
    traced_window = window is not None and not isinstance(window, int)
    if traced_window:
        operands.append(window)
        specs.append(P())
    has_q2 = q2 is not None
    if has_q2:
        operands.extend([q2, k2])
        specs.extend([q_spec, page_spec])
    bps = layout.blocks_per_shard

    def body(qk_, pages_, bt_, len_, *rest):
        rest = list(rest)
        vp = rest.pop(0) if has_v else None
        w = rest.pop(0) if traced_window else window
        q2_, k2_ = rest if has_q2 else (None, None)
        if layout.shard_pages:
            off = jax.lax.axis_index("data") * bps
            bt_ = jnp.where(bt_ >= 0, bt_ - off, bt_)
        return paged_decode_attention(qk_, pages_, vp, bt_, len_, window=w,
                                      q2=q2_, k2=k2_, **kw)

    return shard_map(body, mesh=layout.mesh, in_specs=tuple(specs),
                     out_specs=P("data", "model", None, None),
                     check_rep=False)(*operands)


def paged_update_attend(cache: dict, tensors: dict, block_tables: jax.Array,
                        positions: jax.Array, cache_pos, chunk_valid,
                        dtype, *, fused: bool,
                        scales: Optional[dict] = None) -> tuple:
    """Single entry point for every paged-cache attention interaction.

    Writes the fresh K/V — one decode token (``cache_pos``) or a whole
    prefill chunk (``chunk_valid``) — into physical blocks, then either
    gathers the logical ``(B, S)`` layout (returns ``(new_cache, g, kp)``)
    or, for a fused decode step, returns ``(new_cache, None, None)`` so the
    caller attends block-major KV in place via the Pallas kernel. The
    chunked-prefill continuation always gathers: its multi-token queries
    must attend every earlier chunk through the logical layout.

    ``scales`` (per-entry dequant multipliers) reaches the gather through
    :func:`paged_gather`; callers taking the fused return must hand the
    *same* mapping to the kernel so both read paths dequantize identically.
    The same mapping also drives the *write* side: fresh K/V is divided by
    its entry's scale in f32 before the storage-dtype cast, so a
    calibrated per-layer scale maps each entry's amax into the fp8
    representable range (read paths multiply it back). Unit scales skip the
    divide entirely — the default path stays bit-identical.

    fp8 storage saturates: values beyond the format's finite max are
    clamped to it before the cast (e4m3fn has no inf — an overflow would
    otherwise store NaN and poison every later attention read over that
    block). In-range values are untouched, so sub-amax traffic stays
    bit-identical; a calibrated scale moves the whole range in-bounds and
    the clamp never fires.
    """
    if scales:
        tensors = {
            name: (t if float(scales.get(name, 1.0)) == 1.0
                   else t.astype(jnp.float32) / float(scales[name]))
            for name, t in tensors.items()}

    def _saturate(name, t):
        cd = cache[name].dtype
        if cd.itemsize == 1 and jnp.issubdtype(cd, jnp.floating):
            fmax = float(jnp.finfo(cd).max)
            return jnp.clip(t.astype(jnp.float32), -fmax, fmax)
        return t

    tensors = {name: _saturate(name, t) for name, t in tensors.items()}
    if chunk_valid is not None:
        new_cache = paged_write_chunk(cache, tensors, block_tables,
                                      positions, chunk_valid)
    else:
        assert cache_pos is not None, "paged attention is decode-only"
        new_cache = paged_write(cache, tensors, block_tables, cache_pos)
        if fused:
            return new_cache, None, None
    g, kp = paged_gather(new_cache, block_tables, dtype, scales)
    return new_cache, g, kp


def _fused_paged_attention(cfg: AttnConfig, q: jax.Array, cache: dict,
                           block_tables: jax.Array, positions: jax.Array,
                           window, scales: Optional[dict] = None) -> jax.Array:
    """GQA decode against block-major K/V: one kernel call per layer, no
    ``(B, S)`` gather. ``window`` may be None, int, or a traced scalar
    (scan-mode per-layer windows). ``scales`` carries the same per-entry
    dequant multipliers the gather fallback applies, handed to the kernel
    as its in-register ``k_scale``/``v_scale``. Returns (B, 1, H, Dv)."""
    B, T, H, D = q.shape
    assert T == 1, "fused paged attention is single-query decode"
    Hkv = cfg.n_kv_heads
    qk = q.reshape(B, Hkv, H // Hkv, D)
    lengths = positions[:, 0] + 1
    sc = scales or {}
    o = _paged_kernel_call(
        qk, cache["k"], cache["v"], block_tables, lengths, window=window,
        scale=math.sqrt(D), scale_mode="div", score_dtype=q.dtype,
        probs_dtype=q.dtype, k_scale=float(sc.get("k", 1.0)),
        v_scale=float(sc.get("v", 1.0)), out_dtype=q.dtype)
    return o.reshape(B, 1, H, o.shape[-1])


def _split_heads(x: jax.Array, n: int, d: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], n, d)


def _cache_roundtrip(t: jax.Array, cache_leaf: jax.Array, dtype) -> jax.Array:
    """Pass fresh prefill K/V through the cache storage dtype before
    attending, so prefill attention sees exactly the values every later read
    of the cache sees (fp8 caches: the first token is computed from
    fp8-rounded K/V — the invariant that makes chunked prefill, which attends
    *through* the cache, bit-identical to one-shot prefill)."""
    if cache_leaf.dtype == t.dtype:
        return t
    return t.astype(cache_leaf.dtype).astype(dtype)


def _cache_write_chunk(cache: dict, tensors: dict, positions: jax.Array,
                       valid: jax.Array, start: jax.Array) -> dict:
    """Masked bucketed-prefill write into the dense ring.

    ``positions``: (B, T) absolute positions (``start[:, None] + arange``);
    ``valid``: (B, T) bool marking real tokens (padding sits at the tail);
    ``start``: (B,) — rows with start == 0 get their ``pos`` ring reset to -1
    first (slot reuse must not leak the previous occupant's keys), rows with
    no valid entries (co-batched decoding/vacant slots) are left untouched.
    Ring semantics: only each row's last W valid entries are kept.
    """
    B, T = positions.shape
    W = cache["pos"].shape[1]
    end = start + jnp.sum(valid, axis=1).astype(jnp.int32)         # (B,)
    keep = valid & (positions >= (end - W)[:, None])
    slot = jnp.where(keep, positions % W, W)         # W = out of bounds: drop
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, T))
    new = dict(cache)
    pos0 = jnp.where((start == 0)[:, None], -1, cache["pos"])
    new["pos"] = pos0.at[bidx, slot].set(positions.astype(jnp.int32),
                                         mode="drop")
    for name, t in tensors.items():
        new[name] = cache[name].at[bidx, slot].set(
            t.astype(cache[name].dtype), mode="drop")
    return new


def _ring_logical_gather(cache: dict, names: tuple, dtype,
                         start: jax.Array, valid: jax.Array) -> tuple:
    """Gather a dense ring into ascending *logical* order for chunked-prefill
    continuation: after a chunk ending at absolute position ``end`` lands,
    the ring holds exactly positions ``end - W .. end - 1`` (contiguous
    writes from 0), so logical position ``p`` lives at slot ``p % W``.
    Returns ``({name: (B, W, ...) gathered}, kp)`` with ``kp[b, j] =
    end_b - W + j`` — entries with ``kp < 0`` (ring not yet full) gather
    arbitrary slots and are excluded by ``_mask_from_pos``'s ``k_pos >= 0``
    clause, contributing exact zeros; valid keys appear in the same
    ascending order a one-shot prefill attends them."""
    W = cache["pos"].shape[1]
    end = start + jnp.sum(valid, axis=1).astype(jnp.int32)          # (B,)
    kp = end[:, None] + jnp.arange(-W, 0, dtype=jnp.int32)[None]    # (B, W)
    slot = kp % W                                     # nonneg for kp < 0 too
    bidx = jnp.broadcast_to(jnp.arange(slot.shape[0])[:, None], slot.shape)
    out = {name: cache[name][bidx, slot].astype(dtype) for name in names}
    return out, kp


def _cache_write(cache: dict, tensors: dict, positions: jax.Array,
                 cache_pos: Optional[jax.Array]) -> dict:
    """Write T new entries into the ring buffer. positions: (B, T)."""
    first = next(iter(tensors.values()))
    B, T = first.shape[0], first.shape[1]
    W = cache["pos"].shape[1]
    new = dict(cache)
    if cache_pos is None and T <= W:
        # prefill, fits: contiguous write at slot 0
        for name, t in tensors.items():
            new[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], t.astype(cache[name].dtype), 0, axis=1)
        pos_fill = jnp.full((B, W), -1, jnp.int32)
        new["pos"] = jax.lax.dynamic_update_slice_in_dim(
            pos_fill, positions.astype(jnp.int32), 0, axis=1)
    elif cache_pos is None:
        # prefill longer than the window: keep the last W entries
        idx = (positions[0, T - W:] % W).astype(jnp.int32)
        for name, t in tensors.items():
            new[name] = cache[name].at[:, idx].set(
                t[:, T - W:].astype(cache[name].dtype))
        new["pos"] = cache["pos"].at[:, idx].set(positions[:, T - W:])
    elif getattr(cache_pos, "ndim", 0) == 1:
        # decode with per-sequence positions (continuous batching): each
        # batch row writes its own ring slot
        slot = (cache_pos % W).astype(jnp.int32)          # (B,)
        bidx = jnp.arange(B)
        for name, t in tensors.items():
            new[name] = cache[name].at[bidx, slot].set(
                t[:, 0].astype(cache[name].dtype))
        new["pos"] = cache["pos"].at[bidx, slot].set(
            positions[:, 0].astype(jnp.int32))
    else:
        # decode: single-slot ring write
        slot = (cache_pos % W).astype(jnp.int32)
        for name, t in tensors.items():
            new[name] = jax.lax.dynamic_update_slice_in_dim(
                cache[name], t.astype(cache[name].dtype), slot, axis=1)
        new["pos"] = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), slot, axis=1)
    return new


def _mask_from_pos(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
                   window, valid: Optional[jax.Array]) -> jax.Array:
    """(B, Tq, Tk) boolean mask. window may be None, int, or traced scalar."""
    m = k_pos[:, None, :] >= 0
    if causal:
        m &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        m &= k_pos[:, None, :] > (q_pos[:, :, None] - window)
    if valid is not None:
        m &= valid[:, None, :]
    return m


def attention(p: dict, ctx: QuantContext, scope: str, cfg: AttnConfig,
              x: jax.Array, positions: jax.Array, *,
              kv_x: Optional[jax.Array] = None,
              kv_positions: Optional[jax.Array] = None,
              kv_valid: Optional[jax.Array] = None,
              cache: Optional[dict] = None,
              cache_pos: Optional[jax.Array] = None,
              block_tables: Optional[jax.Array] = None,
              chunk_valid: Optional[jax.Array] = None,
              chunk_start: Optional[jax.Array] = None,
              chunk_ring: bool = False,
              window: Union[None, int, jax.Array] = "cfg",
              cross: bool = False, paged_attn: str = "fused"):
    """Returns (y, new_cache).

    * self-attention:  default. K/V come from ``x`` and are written into
      ``cache`` when given (prefill: cache_pos None; decode: scalar pos).
    * paged decode: ``block_tables`` given with a block-major ``cache`` —
      the new token is scattered into its row's page and, with
      ``paged_attn="fused"`` (the default), attended *in place* by the
      Pallas paged-attention kernel (block-table indirection in-kernel, HBM
      traffic proportional to live tokens). ``paged_attn="gather"`` keeps
      the reference path: K/V gathered back into logical ``(B, S)`` order
      before the (identical) attention math. Layers whose attention BGEMMs
      carry an MP format, probe/registry traces, and chunked-prefill
      continuation always take the gather path (see
      :func:`use_fused_paged`).
    * chunked/bucketed prefill: ``chunk_valid`` (B, T) marks real tokens in
      a padded chunk starting at ``chunk_start`` (B,). Paged: the chunk is
      written straight into physical blocks and attention runs over the
      gathered logical layout (so a continuation chunk sees every earlier
      chunk's keys). Dense: masked ring write + local attention by default
      (single-shot bucketed prefill — continuation-blind);
      ``chunk_ring=True`` instead attends the whole ring gathered into
      logical order, so dense engines can split prompts into chunks too —
      windowed archs additionally need their ring widened to
      ``window + chunk_len`` (``kv_cache_spec(chunk_extra=...)``) or a
      chunk write past an unaligned window boundary evicts keys the
      chunk's earliest query still needs.
    * cross-attention: ``cross=True``; K/V from ``kv_x`` (encoder output) or
      from a pre-computed ``cache`` {"k","v"}; bidirectional, no RoPE.
    * ``window``: "cfg" -> use cfg.window; else override (may be traced).
    """
    B, T, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    if isinstance(window, str) and window == "cfg":
        window = cfg.window

    q = qops.linear(ctx, f"{scope}/q_proj", x, p["q_proj"]["w"],
                    p["q_proj"].get("b"))
    q = _split_heads(q, H, D)

    new_cache = cache
    causal = cfg.causal
    y_fused = None
    if cross:
        causal = False
        if kv_x is not None:
            k = _split_heads(qops.linear(ctx, f"{scope}/k_proj", kv_x,
                                         p["k_proj"]["w"], p["k_proj"].get("b")),
                             Hkv, D)
            v = _split_heads(qops.linear(ctx, f"{scope}/v_proj", kv_x,
                                         p["v_proj"]["w"], p["v_proj"].get("b")),
                             Hkv, D)
        else:  # pre-computed encoder K/V (decode)
            k, v = cache["k"], cache["v"]
        S = k.shape[1]
        kp = kv_positions if kv_positions is not None else jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        mask = _mask_from_pos(jnp.maximum(positions, 0), kp, False, None, kv_valid)
    else:
        # ---- self-attention ----
        k = _split_heads(qops.linear(ctx, f"{scope}/k_proj", x,
                                     p["k_proj"]["w"], p["k_proj"].get("b")), Hkv, D)
        v = _split_heads(qops.linear(ctx, f"{scope}/v_proj", x,
                                     p["v_proj"]["w"], p["v_proj"].get("b")), Hkv, D)
        if cfg.rope_theta is not None:
            sin, cos = rope_table(positions, D, cfg.rope_theta)
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
        if cache is not None and block_tables is not None:
            # paged: a prefill chunk or decode token written straight into
            # physical blocks. Decode attends them in place via the fused
            # kernel when eligible; chunk continuation (and the gather
            # fallback) attends the gathered logical layout, so a
            # continuation chunk sees every earlier chunk's keys.
            fused = (chunk_valid is None and causal
                     and use_fused_paged(ctx, scope, paged_attn)
                     and _mesh_fused_ok(B, Hkv))
            # one mapping feeds both read paths: the kernel's in-register
            # dequant and the gather fallback can never disagree on scales
            kv_scales = dict(cfg.kv_dequant_scales or ())
            new_cache, g, kp = paged_update_attend(
                cache, {"k": k, "v": v}, block_tables, positions, cache_pos,
                chunk_valid, x.dtype, fused=fused, scales=kv_scales)
            if g is None:
                y_fused = _fused_paged_attention(cfg, q, new_cache,
                                                 block_tables, positions,
                                                 window, scales=kv_scales)
            else:
                k, v = g["k"], g["v"]
        elif cache is not None and chunk_valid is not None:
            # dense bucketed prefill: masked ring write, then either local
            # attention over the cache-dtype-rounded fresh K/V (single-shot
            # buckets, flash-capable) or — for chunked continuation
            # (chunk_ring) — attention over the whole ring gathered into
            # logical order, so this chunk's queries see every earlier
            # chunk's keys exactly as later cache reads will
            new_cache = _cache_write_chunk(cache, {"k": k, "v": v},
                                           positions, chunk_valid,
                                           chunk_start)
            if chunk_ring:
                g, kp = _ring_logical_gather(new_cache, ("k", "v"), x.dtype,
                                             chunk_start, chunk_valid)
                k, v = g["k"], g["v"]
            else:
                k = _cache_roundtrip(k, cache["k"], x.dtype)
                v = _cache_roundtrip(v, cache["v"], x.dtype)
                kp = positions
        elif cache is not None:
            new_cache = _cache_write(cache, {"k": k, "v": v}, positions, cache_pos)
            if cache_pos is not None:
                # decode: attend over the ring buffer (upcast fp8 caches)
                k = new_cache["k"].astype(x.dtype)
                v = new_cache["v"].astype(x.dtype)
                kp = new_cache["pos"]
            else:
                # prefill from an empty cache: attend locally (flash-capable),
                # through the cache storage dtype (see _cache_roundtrip)
                k = _cache_roundtrip(k, cache["k"], x.dtype)
                v = _cache_roundtrip(v, cache["v"], x.dtype)
                kp = positions
        else:
            kp = positions
        mask = (None if y_fused is not None else
                _mask_from_pos(positions, kp, causal, window, None))

    # flash for self-attention prefill/training, and for unmasked
    # cross-attention (encoder-decoder at long frame counts)
    # chunked/bucketed prefill never flashes: bucket padding must not flip a
    # prompt across flash_min_seq into a different summation order than its
    # unpadded reference (engines route bucket >= flash_min_seq prompts to
    # the legacy per-length prefill instead)
    use_flash = (y_fused is None and cache_pos is None
                 and T >= cfg.flash_min_seq
                 and ctx.mode != "probe" and block_tables is None
                 and chunk_valid is None
                 and ((not cross and T == k.shape[1])
                      or (cross and kv_x is not None and kv_valid is None)))
    if y_fused is not None:
        y = y_fused
    elif use_flash:
        from repro.nn.flash import flash_attention
        y = flash_attention(ctx, scope, q, k, v, positions,
                            causal=causal and not cross,
                            window=window if not cross else None,
                            block=cfg.flash_block)
    else:
        y = _reference_attention(ctx, scope, q, k, v, mask)

    y = y.reshape(B, T, H * D)
    y = qops.linear(ctx, f"{scope}/o_proj", y, p["o_proj"]["w"])
    return y, new_cache


def cross_kv(p: dict, ctx: QuantContext, scope: str, cfg: AttnConfig,
             enc_out: jax.Array) -> dict:
    """Pre-compute encoder K/V for decode-time cross-attention."""
    Hkv, D = cfg.n_kv_heads, cfg.d_head
    k = _split_heads(qops.linear(ctx, f"{scope}/k_proj", enc_out,
                                 p["k_proj"]["w"], p["k_proj"].get("b")), Hkv, D)
    v = _split_heads(qops.linear(ctx, f"{scope}/v_proj", enc_out,
                                 p["v_proj"]["w"], p["v_proj"].get("b")), Hkv, D)
    return {"k": k, "v": v}


def _reference_attention(ctx, scope, q, k, v, mask):
    """Materialized-scores attention; the calibration/probe path."""
    B, T, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    Dv = v.shape[-1]
    qg = q.reshape(B, T, Hkv, G, D)
    # L_BGEMM op #1: qk_matmul
    scores = qops.bgemm(ctx, f"{scope}/qk_matmul", "BTKGD,BSKD->BKGTS", qg, k)
    scores = scores.astype(jnp.float32) / math.sqrt(D)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask[:, None, None, :, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    # L_BGEMM op #2: av_matmul
    y = qops.bgemm(ctx, f"{scope}/av_matmul", "BKGTS,BSKD->BTKGD", probs, v)
    return y.reshape(B, T, H, Dv)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    rope_theta: float = 10000.0
    flash_min_seq: int = 4096
    flash_block: int = 1024
    # decode-time weight absorption (DeepSeek's own serving optimization):
    # score/attend directly in the latent space instead of re-expanding
    # per-head K/V over the whole cache every step. Off by default =
    # paper-faithful baseline; enabled as a §Perf iteration.
    absorb_decode: bool = False
    # paged KV-read dequant multipliers, as in AttnConfig.kv_dequant_scales
    # (entries: "ckv", "kr"). Applied on the gather read path; the fused
    # absorbed-decode kernel rejects non-unit scales (its f32 dequant point
    # differs from the gather path's bf16 rounding, so bitwise parity is
    # impossible) — fail fast instead of silently diverging.
    kv_dequant_scales: Optional[tuple] = None


def mla_specs(prefix: str, cfg: MLAConfig) -> dict:
    dm, H = cfg.d_model, cfg.n_heads
    r_q, r_kv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    return {
        f"{prefix}/q_a_proj/w": ParamSpec((r_q, dm), (None, "embed"),
                                          init="scaled_normal"),
        f"{prefix}/q_norm/scale": ParamSpec((r_q,), (None,), jnp.float32, "ones"),
        f"{prefix}/q_b_proj/w": ParamSpec((H * (dn + dr), r_q), ("heads", None),
                                          init="scaled_normal"),
        f"{prefix}/kv_a_proj/w": ParamSpec((r_kv + dr, dm), (None, "embed"),
                                           init="scaled_normal"),
        f"{prefix}/kv_norm/scale": ParamSpec((r_kv,), (None,), jnp.float32, "ones"),
        f"{prefix}/kv_b_proj/w": ParamSpec((H * (dn + dv), r_kv), ("heads", None),
                                           init="scaled_normal"),
        f"{prefix}/o_proj/w": ParamSpec((dm, H * dv), ("embed", "heads"),
                                        init="scaled_normal"),
    }


def mla_cache_spec(cfg: MLAConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> dict:
    # sequence-sharded latent cache: scores/context contract tiny per-shard
    # partials (the right decode sharding for MQA-like shared-KV caches);
    # kv_lora picks up 'model' only when kv_seq can't (tiny max_len)
    return {
        "ckv": ParamSpec((batch, max_len, cfg.kv_lora_rank),
                         ("act_batch", "kv_seq", "kv_lora"), dtype, "zeros"),
        "kr": ParamSpec((batch, max_len, cfg.qk_rope_dim),
                        ("act_batch", "kv_seq", None), dtype, "zeros"),
        "pos": ParamSpec((batch, max_len), ("act_batch", "kv_seq"), jnp.int32,
                         "zeros"),
    }


def mla_page_spec(cfg: MLAConfig, n_blocks: int, block_size: int,
                  dtype=jnp.bfloat16) -> dict:
    """Paged latent KV storage (see :func:`kv_page_spec` for semantics)."""
    return {
        "ckv": ParamSpec((n_blocks, block_size, cfg.kv_lora_rank),
                         ("kv_blocks", None, "kv_lora"), dtype, "zeros"),
        "kr": ParamSpec((n_blocks, block_size, cfg.qk_rope_dim),
                        ("kv_blocks", None, None), dtype, "zeros"),
    }


def mla_attention(p: dict, ctx: QuantContext, scope: str, cfg: MLAConfig,
                  x: jax.Array, positions: jax.Array, *,
                  cache: Optional[dict] = None,
                  cache_pos: Optional[jax.Array] = None,
                  block_tables: Optional[jax.Array] = None,
                  chunk_valid: Optional[jax.Array] = None,
                  chunk_start: Optional[jax.Array] = None,
                  chunk_ring: bool = False,
                  paged_attn: str = "fused"):
    """MLA; latent KV cache {"ckv","kr","pos"}; returns (y, new_cache).
    ``chunk_valid``/``chunk_start`` select chunked/bucketed prefill (see
    :func:`attention`); chunk attention always uses the expanded (non-
    absorbed) path, matching one-shot prefill. Paged *absorbed* decode takes
    the fused kernel by default (``paged_attn="fused"``), scoring/attending
    the block-major latents in place; the expanded decode path re-expands
    per-head K/V over the whole cache and therefore always gathers."""
    B, T, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim

    qa = qops.linear(ctx, f"{scope}/q_a_proj", x, p["q_a_proj"]["w"])
    qa = apply_norm(p["q_norm"], qa)
    q = qops.linear(ctx, f"{scope}/q_b_proj", qa, p["q_b_proj"]["w"])
    q = q.reshape(B, T, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]

    kva = qops.linear(ctx, f"{scope}/kv_a_proj", x, p["kv_a_proj"]["w"])
    ckv, kr = kva[..., :cfg.kv_lora_rank], kva[..., cfg.kv_lora_rank:]
    ckv = apply_norm(p["kv_norm"], ckv)

    sin, cos = rope_table(positions, dr, cfg.rope_theta)
    qr = apply_rope(qr, sin, cos)
    kr = apply_rope(kr[:, :, None, :], sin, cos)[:, :, 0, :]

    new_cache = cache
    if cache is not None and block_tables is not None:
        # paged: fused absorbed decode scores the block-major latents in
        # place; chunk continuation and the expanded/fallback paths gather.
        # Non-unit dequant scales also route to the gather path: the fused
        # kernel's f32 dequant point cannot reproduce the gather path's
        # bf16 rounding, and raising mid-drain (the old fail-fast in
        # _mla_decode_absorbed_paged, kept as a backstop) would kill a
        # serving engine that merely loaded a scaled-fp8 checkpoint.
        kv_scales = dict(cfg.kv_dequant_scales or ())
        unit_scales = all(float(kv_scales.get(n, 1.0)) == 1.0
                          for n in ("ckv", "kr"))
        fused = (chunk_valid is None and cfg.absorb_decode and unit_scales
                 and use_fused_paged(ctx, scope, paged_attn)
                 and _mesh_fused_ok(B, 1))
        new_cache, g, kp = paged_update_attend(
            cache, {"ckv": ckv, "kr": kr}, block_tables, positions,
            cache_pos, chunk_valid, x.dtype, fused=fused, scales=kv_scales)
        if g is None:
            return _mla_decode_absorbed_paged(p, ctx, scope, cfg, qn, qr,
                                              new_cache, block_tables,
                                              positions, scales=kv_scales)
        ckv, kr = g["ckv"], g["kr"]
        if chunk_valid is None and cfg.absorb_decode:
            return _mla_decode_absorbed(p, ctx, scope, cfg, qn, qr, ckv,
                                        kr, positions, kp, new_cache)
    elif cache is not None and chunk_valid is not None:
        new_cache = _cache_write_chunk(cache, {"ckv": ckv, "kr": kr},
                                       positions, chunk_valid, chunk_start)
        if chunk_ring:
            # dense chunked continuation: attend the whole latent ring in
            # logical order (MLA caches are full-length, W = max_len)
            g, kp = _ring_logical_gather(new_cache, ("ckv", "kr"), x.dtype,
                                         chunk_start, chunk_valid)
            ckv, kr = g["ckv"], g["kr"]
        else:
            ckv = _cache_roundtrip(ckv, cache["ckv"], x.dtype)
            kr = _cache_roundtrip(kr, cache["kr"], x.dtype)
            kp = positions
    elif cache is not None:
        new_cache = _cache_write(cache, {"ckv": ckv, "kr": kr}, positions,
                                 cache_pos)
        if cache_pos is not None:
            ckv = new_cache["ckv"].astype(x.dtype)
            kr = new_cache["kr"].astype(x.dtype)
            kp = new_cache["pos"]
            if cfg.absorb_decode:
                return _mla_decode_absorbed(p, ctx, scope, cfg, qn, qr, ckv,
                                            kr, positions, kp, new_cache)
        else:
            # prefill from empty cache: attend locally, through the cache
            # storage dtype (see _cache_roundtrip)
            ckv = _cache_roundtrip(ckv, cache["ckv"], x.dtype)
            kr = _cache_roundtrip(kr, cache["kr"], x.dtype)
            kp = positions
    else:
        kp = positions

    # Expand latents to per-head K (nope part) and V. The expanded tensors
    # are the big ones at 32k prefill — pin their head dim to 'model'.
    from repro.distributed.sharding import shard_hint
    kvb = qops.linear(ctx, f"{scope}/kv_b_proj", ckv, p["kv_b_proj"]["w"])
    S = ckv.shape[1]
    kvb = kvb.reshape(B, S, H, dn + dv)
    kvb = shard_hint(kvb, ("pod", "data"), None, "model", None)
    kn, v = kvb[..., :dn], kvb[..., dn:]

    mask = _mask_from_pos(positions, kp, True, None, None)

    qf = jnp.concatenate([qn, qr], axis=-1)
    qf = shard_hint(qf, ("pod", "data"), None, "model", None)
    kf = jnp.concatenate([kn, jnp.broadcast_to(kr[:, :, None, :], (B, S, H, dr))],
                         axis=-1)
    kf = shard_hint(kf, ("pod", "data"), None, "model", None)
    use_flash = (cache_pos is None and T >= cfg.flash_min_seq and T == S
                 and block_tables is None and chunk_valid is None)
    if use_flash:
        from repro.nn.flash import flash_attention
        y = flash_attention(ctx, scope, qf, kf, v, positions, causal=True,
                            window=None, block=cfg.flash_block)
    else:
        y = _reference_attention(ctx, scope, qf, kf, v, mask)
    y = y.reshape(B, T, H * dv)
    y = qops.linear(ctx, f"{scope}/o_proj", y, p["o_proj"]["w"])
    return y, new_cache


def _mla_decode_absorbed(p, ctx, scope, cfg: MLAConfig, qn, qr, ckv, kr,
                         positions, kp, new_cache):
    """Latent-space MLA decode: absorb W_UK into q and W_UV into the output.

    Per token: scores = (qn W_uk) . ckv + qr . kr, attention over the latent
    cache directly — O(S * (r_kv + d_rope)) per head instead of re-expanding
    (S, H, dn+dv) K/V from the latent every step.
    """
    import math as _math
    B, T, H, dn = qn.shape
    r = cfg.kv_lora_rank
    dv = cfg.v_head_dim
    # f32 operand casts: some bf16 batched-dot layouts are unimplemented on
    # the CPU backend; on TPU XLA folds the converts into the MXU op
    wkv = p["kv_b_proj"]["w"].reshape(H, dn + dv, r).astype(jnp.float32)
    w_uk, w_uv = wkv[:, :dn, :], wkv[:, dn:, :]
    # q' = qn @ W_uk  (per head) — the "absorb" GEMM
    q_lat = qops.qeinsum(ctx, f"{scope}/q_absorb", "BTHh,Hhr->BTHr",
                         qn.astype(jnp.float32), w_uk, kind="linear")
    # latent scores + rope scores (the quantizable qk_matmul analogue)
    s_lat = qops.bgemm(ctx, f"{scope}/qk_matmul", "BTHr,BSr->BHTS", q_lat, ckv)
    s_rope = jnp.einsum("BTHd,BSd->BHTS", qr.astype(jnp.float32),
                        kr.astype(jnp.float32))
    scale = 1.0 / _math.sqrt(dn + cfg.qk_rope_dim)
    s = (s_lat.astype(jnp.float32) + s_rope) * scale
    mask = _mask_from_pos(positions, kp, True, None, None)
    s = jnp.where(mask[:, None, :, :], s, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(s, axis=-1)
    # context in latent space, then expand through W_uv (av_matmul analogue)
    ctx_lat = qops.bgemm(ctx, f"{scope}/av_matmul", "BHTS,BSr->BTHr", probs,
                         ckv.astype(jnp.float32))
    y = qops.qeinsum(ctx, f"{scope}/v_absorb", "BTHr,Hvr->BTHv", ctx_lat,
                     w_uv, kind="linear")
    y = y.reshape(B, T, H * dv).astype(qn.dtype)
    y = qops.linear(ctx, f"{scope}/o_proj", y, p["o_proj"]["w"])
    return y, new_cache


def _mla_decode_absorbed_paged(p, ctx, scope, cfg: MLAConfig, qn, qr,
                               new_cache, block_tables, positions,
                               scales: Optional[dict] = None):
    """Fused-kernel twin of :func:`_mla_decode_absorbed`: the latent scores
    (``q_lat . ckv + qr . kr``) and the latent context are computed directly
    against the block-major latent cache — MQA-shaped (one shared KV "head",
    H query heads), values taken from the same ``ckv`` blocks as the keys.
    The absorb GEMMs (``q_absorb`` / ``v_absorb``) stay on ``qops`` so their
    MP formats and op names are untouched; the in-kernel math mirrors the
    reference bitwise up to f32 summation order."""
    import math as _math
    B, T, H, dn = qn.shape
    assert T == 1, "fused paged MLA is single-query decode"
    r = cfg.kv_lora_rank
    dv = cfg.v_head_dim
    wkv = p["kv_b_proj"]["w"].reshape(H, dn + dv, r).astype(jnp.float32)
    w_uk, w_uv = wkv[:, :dn, :], wkv[:, dn:, :]
    q_lat = qops.qeinsum(ctx, f"{scope}/q_absorb", "BTHh,Hhr->BTHr",
                         qn.astype(jnp.float32), w_uk, kind="linear")
    lengths = positions[:, 0] + 1
    sc = scales or {}
    if any(float(sc.get(n, 1.0)) != 1.0 for n in ("ckv", "kr")):
        # the kernel dequantizes to the f32 query dtype while the gather
        # path rounds the scaled latents through the bf16 activation dtype,
        # so non-unit scales cannot stay bit-identical between the two —
        # refuse rather than silently diverge
        raise ValueError(
            f"{scope}: fused absorbed MLA decode does not support non-unit "
            f"kv_dequant_scales (got {sc}); use paged_attn='gather'")
    ctx_lat = _paged_kernel_call(
        q_lat.reshape(B, 1, H, r),                      # (B, Hkv=1, G=H, r)
        new_cache["ckv"][:, :, None, :], None,          # v = ckv (latent)
        block_tables, lengths,
        q2=qr.astype(jnp.float32).reshape(B, 1, H, cfg.qk_rope_dim),
        k2=new_cache["kr"][:, :, None, :],
        scale=1.0 / _math.sqrt(dn + cfg.qk_rope_dim), scale_mode="mul",
        k_scale=1.0, v_scale=1.0,  # non-unit scales rejected above
        out_dtype=jnp.float32)
    ctx_lat = ctx_lat.reshape(B, T, H, r)
    y = qops.qeinsum(ctx, f"{scope}/v_absorb", "BTHr,Hvr->BTHv", ctx_lat,
                     w_uv, kind="linear")
    y = y.reshape(B, T, H * dv).astype(qn.dtype)
    y = qops.linear(ctx, f"{scope}/o_proj", y, p["o_proj"]["w"])
    return y, new_cache
