"""Chunked cross-entropy: the (tokens x vocab) logits tensor never
materializes. Each sequence chunk computes head-matmul + CE inside a
``jax.checkpoint`` so the backward pass recomputes chunk logits instead of
stashing them as scan residuals (the difference between ~0.3GB and ~13GB per
device at 50k-256k vocabularies).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["chunked_ce_loss"]


def chunked_ce_loss(head_fn: Callable, h: jax.Array, labels: jax.Array,
                    weights: Optional[jax.Array], chunk: int,
                    no_scan: bool = False) -> jax.Array:
    """head_fn(h_chunk) -> logits. h: (B, T, D); labels/weights: (B, T)."""
    B, T, _ = h.shape
    C = T if no_scan else min(chunk, T)
    n_chunks = -(-T // C)
    padT = n_chunks * C - T
    if weights is None:
        weights = jnp.ones((B, T), jnp.float32)
    if padT:
        h = jnp.pad(h, ((0, 0), (0, padT), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, padT)))
        weights = jnp.pad(weights, ((0, 0), (0, padT)))

    hc = h.reshape(B, n_chunks, C, -1).swapaxes(0, 1)
    lc = labels.reshape(B, n_chunks, C).swapaxes(0, 1)
    wc = weights.reshape(B, n_chunks, C).swapaxes(0, 1)

    def chunk_loss(carry, xs):
        h_i, l_i, w_i = xs
        logits = head_fn(h_i).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_i[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * w_i
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(w_i)), None

    # probe mode must not remat: capture collections cannot cross the
    # checkpoint trace boundary
    body = chunk_loss if no_scan else jax.checkpoint(chunk_loss)
    zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    if n_chunks == 1:
        (total, denom), _ = body(zero, (hc[0], lc[0], wc[0]))
    else:
        (total, denom), _ = jax.lax.scan(body, zero, (hc, lc, wc))
    return total / jnp.maximum(denom, 1.0)
