"""Mamba-2 (SSD, state-space duality) block — chunked matmul formulation.

The chunked SSD algorithm (Dao & Gu, arXiv:2405.21060) recasts the selective
state-space recurrence as block matmuls (MXU-friendly): intra-chunk quadratic
attention-like products + an inter-chunk state recurrence (tiny scan). The
heavy matmuls are routed through ``qops.bgemm`` so the paper's MP machinery
covers them (arch-adaptation: mamba has no attention BGEMMs; these are its
equivalents).

Decode is the classic O(1) state update — this is what makes ``long_500k``
runnable for SSM/hybrid archs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import apply_norm
from repro.nn.spec import ParamSpec
from repro.quant import qops
from repro.quant.qops import QuantContext

__all__ = ["SSMConfig", "mamba_specs", "apply_mamba", "mamba_cache_spec",
           "apply_mamba_decode"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_inner: int
    d_state: int
    head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 256

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_specs(prefix: str, cfg: SSMConfig) -> dict:
    dm, di, N, G, H = (cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_groups,
                       cfg.n_heads)
    d_in_proj = 2 * di + 2 * G * N + H  # z, x, B, C, dt
    return {
        f"{prefix}/in_proj/w": ParamSpec((d_in_proj, dm), ("ssm_inner", "embed"),
                                         init="scaled_normal"),
        f"{prefix}/conv/w": ParamSpec((cfg.d_conv, cfg.conv_dim),
                                      (None, "ssm_inner"), init="scaled_normal"),
        f"{prefix}/conv/b": ParamSpec((cfg.conv_dim,), ("ssm_inner",), init="zeros"),
        f"{prefix}/A_log": ParamSpec((H,), ("ssm_heads",), jnp.float32, "zeros"),
        f"{prefix}/D": ParamSpec((H,), ("ssm_heads",), jnp.float32, "ones"),
        f"{prefix}/dt_bias": ParamSpec((H,), ("ssm_heads",), jnp.float32, "zeros"),
        f"{prefix}/norm/scale": ParamSpec((di,), ("ssm_inner",), jnp.float32, "ones"),
        f"{prefix}/out_proj/w": ParamSpec((dm, di), ("embed", "ssm_inner"),
                                          init="scaled_normal"),
    }


def _split_proj(cfg: SSMConfig, zxbcdt: jax.Array):
    di, G, N, H = cfg.d_inner, cfg.n_groups, cfg.d_state, cfg.n_heads
    z, xbc, dt = jnp.split(zxbcdt, [di, di + cfg.conv_dim], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 hist: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv over time. xbc: (B,T,Cc); w: (k,Cc).
    ``hist``: (B, k-1, Cc) left context from a previous prefill chunk (zeros
    reproduce the plain zero-padded conv bit-for-bit)."""
    k = w.shape[0]
    if hist is None:
        pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([hist.astype(xbc.dtype), xbc], axis=1)
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(ctx: QuantContext, scope: str, cfg: SSMConfig,
                 x: jax.Array, dt: jax.Array, B_: jax.Array, C_: jax.Array,
                 A: jax.Array, init_state: Optional[jax.Array] = None):
    """x:(B,T,H,P) dt:(B,T,H) B_/C_:(B,T,G,N). Returns (y, final_state)."""
    Bb, T, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    Q = min(cfg.chunk, T)
    nc = -(-T // Q)
    padT = nc * Q - T
    if padT:
        x = jnp.pad(x, ((0, 0), (0, padT), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padT), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, padT), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, padT), (0, 0), (0, 0)))

    rep = H // G
    Bh = jnp.repeat(B_, rep, axis=2)  # (B,T,H,N)
    Ch = jnp.repeat(C_, rep, axis=2)

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = Bh.reshape(Bb, nc, Q, H, N)
    Cc = Ch.reshape(Bb, nc, Q, H, N)

    dA = dtc * A  # (B,nc,Q,H) ; A negative
    cum = jnp.cumsum(dA, axis=2)
    # intra-chunk decay matrix L (B,nc,H,Q,Q), lower-triangular
    cq = jnp.moveaxis(cum, 3, 2)  # (B,nc,H,Q)
    L = jnp.exp(cq[..., :, None] - cq[..., None, :])
    L = jnp.where(jnp.tril(jnp.ones((Q, Q), bool)), L, 0.0)

    # scores = C_q . B_k  (quantizable: the SSD analogue of qk_matmul)
    scores = qops.bgemm(ctx, f"{scope}/cb_matmul", "bcqhn,bckhn->bchqk",
                        Cc, Bc)
    att = scores.astype(jnp.float32) * L * jnp.moveaxis(dtc, 3, 2)[..., None, :]
    att = att.astype(x.dtype)
    y_diag = qops.bgemm(ctx, f"{scope}/att_x_matmul", "bchqk,bckhp->bcqhp",
                        att, xc)

    # chunk states: sum_k B_k dt_k decay_k x_k  -> (B,nc,H,P,N)
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum)          # (B,nc,Q,H)
    Bx = (Bc * (decay_states * dtc)[..., None]).astype(x.dtype)
    states = qops.bgemm(ctx, f"{scope}/bx_matmul", "bckhn,bckhp->bchpn",
                        Bx, xc)

    # inter-chunk recurrence (tiny scan over chunks)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                  # (B,nc,H)
    s0 = (jnp.zeros((Bb, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(s, inp):
        dcy, st = inp
        s_new = s * dcy[:, :, None, None] + st.astype(jnp.float32)
        return s_new, s

    (final_state, prev_states) = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(states, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (B,nc,H,P,N)

    # off-diagonal contribution: C_q . state, scaled by in-chunk decay
    Cdec = (Cc * jnp.exp(cum)[..., None]).astype(x.dtype)
    y_off = qops.bgemm(ctx, f"{scope}/c_state_matmul", "bcqhn,bchpn->bcqhp",
                       Cdec, prev_states.astype(x.dtype))

    y = (y_diag.astype(jnp.float32) + y_off.astype(jnp.float32))
    y = y.reshape(Bb, nc * Q, H, P)[:, :T]
    return y.astype(x.dtype), final_state


def apply_mamba(p: dict, ctx: QuantContext, scope: str, cfg: SSMConfig,
                x: jax.Array, cache: Optional[dict] = None, *,
                chunk_valid: Optional[jax.Array] = None,
                resume: Optional[jax.Array] = None):
    """Full-sequence SSD. Returns (y, new_cache).

    Chunked/bucketed prefill: ``chunk_valid`` (B, T) marks real tokens in a
    padded chunk and ``resume`` (B,) selects rows continuing an earlier
    chunk — those seed the causal conv with the cached (d_conv-1)-token tail
    and the SSD recurrence with the cached state. Padded positions get
    dt = 0, which makes them exact identities in the state recurrence (decay
    exp(0) = 1, contribution 0), so rows with no valid tokens (co-batched
    decoding slots) pass their state through bit-unchanged. Bit-exact resume
    additionally needs chunk boundaries aligned to multiples of ``chunk``
    (the engine enforces chunk_len % cfg.chunk == 0): the SSD decomposition
    is then identical to the one-shot computation.
    """
    B, T, _ = x.shape
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = qops.linear(ctx, f"{scope}/in_proj", x, p["in_proj"]["w"])
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    hist = None
    if chunk_valid is not None:
        assert cache is not None and resume is not None
        hist = jnp.where(resume[:, None, None],
                         cache["conv"].astype(xbc_raw.dtype), 0)
    xbc = _causal_conv(xbc_raw, p["conv"]["w"], p["conv"]["b"], hist=hist)
    xs, B_, C_ = jnp.split(xbc, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    xs = xs.reshape(B, T, H, P)
    B_ = B_.reshape(B, T, G, N)
    C_ = C_.reshape(B, T, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    if chunk_valid is not None:
        dt = jnp.where(chunk_valid[..., None], dt, 0.0)
    A = -jnp.exp(p["A_log"])

    init_state = None
    if chunk_valid is not None:
        init_state = jnp.where(resume[:, None, None, None],
                               cache["state"].astype(jnp.float32), 0.0)
    y, state = _ssd_chunked(ctx, scope, cfg, xs, dt, B_, C_, A,
                            init_state=init_state)
    y = y + xs * p["D"][:, None].astype(x.dtype)
    y = y.reshape(B, T, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = apply_norm(p["norm"], y)
    out = qops.linear(ctx, f"{scope}/out_proj", y, p["out_proj"]["w"])

    new_cache = None
    if cache is not None:
        if chunk_valid is not None:
            # per-row tail: the last (d_conv-1) features *before* each row's
            # padding, crossing into the carried history when the chunk is
            # shorter than the conv window
            ext = jnp.concatenate([hist, xbc_raw], axis=1)
            vlen = jnp.sum(chunk_valid, axis=1).astype(jnp.int32)
            idx = vlen[:, None] + jnp.arange(cfg.d_conv - 1,
                                             dtype=jnp.int32)[None]
            tail = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
        else:
            # store the last (d_conv-1) pre-conv features + final SSM state
            tail = xbc_raw[:, -(cfg.d_conv - 1):, :]
            padt = cfg.d_conv - 1 - tail.shape[1]
            if padt > 0:
                tail = jnp.pad(tail, ((0, 0), (padt, 0), (0, 0)))
        new_cache = dict(cache, conv=tail.astype(cache["conv"].dtype),
                         state=state.astype(cache["state"].dtype))
    return out, new_cache


def mamba_cache_spec(cfg: SSMConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "conv": ParamSpec((batch, cfg.d_conv - 1, cfg.conv_dim),
                          ("act_batch", None, "ssm_inner"), dtype, "zeros"),
        "state": ParamSpec((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                           ("act_batch", "ssm_heads", None, None), jnp.float32,
                           "zeros"),
    }


def apply_mamba_decode(p: dict, ctx: QuantContext, scope: str, cfg: SSMConfig,
                       x: jax.Array, cache: dict,
                       row_valid: Optional[jax.Array] = None):
    """Single-token recurrent update. x: (B, 1, C). Returns (y, new_cache).
    ``row_valid`` (B,) bool: rows marked False keep their conv history and
    SSM state bit-unchanged (vacant or mid-prefill slots in a continuous
    decode batch — their garbage token must not advance real state)."""
    B = x.shape[0]
    H, P, G, N = cfg.n_heads, cfg.head_dim, cfg.n_groups, cfg.d_state
    zxbcdt = qops.linear(ctx, f"{scope}/in_proj", x, p["in_proj"]["w"])
    z, xbc, dt = _split_proj(cfg, zxbcdt)          # (B,1,*)
    conv_hist = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    w, b = p["conv"]["w"], p["conv"]["b"]
    k = w.shape[0]
    conv_out = sum(conv_hist[:, -k + i, :] * w[i] for i in range(k)) + b
    xbc1 = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)  # (B,Cc)
    xs, B_, C_ = jnp.split(xbc1, [cfg.d_inner, cfg.d_inner + G * N], axis=-1)
    xs = xs.reshape(B, H, P)
    B_ = jnp.repeat(B_.reshape(B, G, N), H // G, axis=1)
    C_ = jnp.repeat(C_.reshape(B, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                               # (B,H)

    state = cache["state"].astype(jnp.float32)
    dBx = jnp.einsum("bhp,bhn->bhpn", (dt[..., None] * xs.astype(jnp.float32)),
                     B_.astype(jnp.float32))
    state = state * dA[:, :, None, None] + dBx
    y = qops.bgemm(ctx, f"{scope}/c_state_matmul", "bhn,bhpn->bhp",
                   C_.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = apply_norm(p["norm"], y)
    out = qops.linear(ctx, f"{scope}/out_proj", y, p["out_proj"]["w"])
    new_conv = conv_hist[:, 1:]
    if row_valid is not None:
        state = jnp.where(row_valid[:, None, None, None], state,
                          cache["state"].astype(jnp.float32))
        new_conv = jnp.where(row_valid[:, None, None], new_conv,
                             cache["conv"].astype(new_conv.dtype))
    new_cache = dict(cache, conv=new_conv.astype(cache["conv"].dtype),
                     state=state.astype(cache["state"].dtype))
    return out, new_cache
