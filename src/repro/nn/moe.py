"""Mixture-of-Experts layer: top-k routing, capacity-based one-hot dispatch,
optional shared experts (DeepSeek-V3 / Moonlight style).

TPU-friendly implementation: token chunks are processed with a ``lax.scan``
so the (tokens x experts x capacity) dispatch tensor stays VMEM-sized
regardless of global batch. Expert weights carry an ``experts`` logical axis
(sharded over the ``model`` mesh axis = expert parallelism; XLA inserts the
all-to-all around the grouped GEMMs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.layers import mlp_specs, apply_mlp
from repro.nn.spec import ParamSpec
from repro.quant import qops
from repro.quant.qops import QuantContext

__all__ = ["MoEConfig", "moe_specs", "apply_moe"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared_experts: int = 0
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    token_chunk: int = 1024       # scan chunk (memory knob)
    router_dtype: str = "float32"
    aux_loss_weight: float = 0.001


def moe_specs(prefix: str, d_model: int, cfg: MoEConfig,
              activation: str = "swiglu") -> dict:
    E, dff = cfg.n_experts, cfg.d_expert_ff
    specs = {
        f"{prefix}/router/w": ParamSpec((E, d_model), ("experts", "embed"),
                                        jnp.float32, "scaled_normal"),
        f"{prefix}/experts/gate_proj/w": ParamSpec(
            (E, dff, d_model), ("experts", "ffn", "embed"), init="scaled_normal"),
        f"{prefix}/experts/up_proj/w": ParamSpec(
            (E, dff, d_model), ("experts", "ffn", "embed"), init="scaled_normal"),
        f"{prefix}/experts/down_proj/w": ParamSpec(
            (E, d_model, dff), ("experts", "embed", "ffn"), init="scaled_normal"),
    }
    if cfg.n_shared_experts:
        specs.update(mlp_specs(f"{prefix}/shared", d_model,
                               cfg.d_shared_ff * cfg.n_shared_experts, activation))
    return specs


def apply_moe(p: dict, ctx: QuantContext, scope: str, x: jax.Array,
              cfg: MoEConfig, activation: str = "swiglu"):
    """x: (B, T, C) -> (y, aux_loss)."""
    B, T, C = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(B * T, C)
    N = B * T

    chunk = min(cfg.token_chunk, N)
    if ctx.mode == "probe":
        chunk = N  # probe/capture collections cannot cross a scan boundary
    n_chunks = -(-N // chunk)
    pad = n_chunks * chunk - N
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    cap = max(1, int(chunk * K / E * cfg.capacity_factor))
    # MXU-friendly capacity
    cap = -(-cap // 8) * 8

    xc = xt.reshape(n_chunks, chunk, C)

    def one_chunk(carry, xi):
        logits = qops.linear(ctx, f"{scope}/router", xi.astype(jnp.float32),
                             p["router"]["w"])
        probs = jax.nn.softmax(logits, axis=-1)           # (t, E)
        topv, topi = jax.lax.top_k(probs, K)              # (t, K)
        topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)
        onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)      # (t, K, E)
        # position of each (token, k) within its expert queue
        pos = jnp.cumsum(onehot.reshape(-1, E), axis=0).reshape(chunk, K, E)
        pos = (pos - 1.0) * onehot                         # 0-based, 0 elsewhere
        keep = (pos < cap) & (onehot > 0)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        # dispatch (t, E, cap)
        disp = jnp.einsum("tke,tkec->tec", onehot * keep, pos_oh)
        comb = jnp.einsum("tk,tke,tkec->tec", topv, onehot * keep, pos_oh)
        xe = jnp.einsum("tec,tC->eCc", disp, xi.astype(jnp.float32))
        xe = jnp.transpose(xe, (0, 2, 1)).astype(x.dtype)  # (E, cap, C)
        g = qops.linear(ctx, f"{scope}/experts/gate_proj", xe,
                        p["experts"]["gate_proj"]["w"])
        u = qops.linear(ctx, f"{scope}/experts/up_proj", xe,
                        p["experts"]["up_proj"]["w"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        ye = qops.linear(ctx, f"{scope}/experts/down_proj", h,
                         p["experts"]["down_proj"]["w"])   # (E, cap, C)
        yi = jnp.einsum("tec,ecC->tC", comb, ye.astype(jnp.float32))
        # load-balance aux (Switch): E * sum_e f_e * P_e
        f_e = jnp.mean(jnp.sum(onehot, 1), axis=0)
        p_e = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f_e * p_e)
        return carry + aux, yi.astype(x.dtype)

    if n_chunks == 1:
        aux_total, ys = one_chunk(jnp.zeros((), jnp.float32), xc[0])
        ys = ys[None]
    else:
        aux_total, ys = jax.lax.scan(one_chunk, jnp.zeros((), jnp.float32), xc)
    y = ys.reshape(n_chunks * chunk, C)[:N].reshape(B, T, C)

    if cfg.n_shared_experts:
        y = y + apply_mlp(p["shared"], ctx, f"{scope}/shared", x, activation)
    return y, cfg.aux_loss_weight * aux_total / n_chunks
