"""Specs-first parameter system.

Every model exposes ``param_specs(cfg) -> dict[path -> ParamSpec]`` (a flat
dict keyed by '/'-separated paths). From the specs we derive, without any
allocation:

* abstract parameters (``jax.ShapeDtypeStruct``) for ``.lower()`` dry-runs,
* ``NamedSharding`` trees via the logical-axis rules in
  ``repro.distributed.sharding``,
* real initialized parameters (for smoke tests / training).

Keeping specs separate from values keeps the multi-pod dry-run cheap: the
production mesh only ever sees shapes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["ParamSpec", "init_params", "abstract_params", "tree_from_flat",
           "flatten_paths", "param_count", "param_bytes"]


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical_axes: tuple          # one logical axis name (or None) per dim
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"         # normal | zeros | ones | scaled_normal
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"shape {self.shape} vs axes {self.logical_axes}")


def _init_one(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    scale = spec.init_scale
    if spec.init == "scaled_normal":  # 1/sqrt(fan_in) init
        fan_in = spec.shape[-1] if len(spec.shape) > 1 else spec.shape[0]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)


def tree_from_flat(flat: dict) -> dict:
    """'a/b/c' flat dict -> nested dicts."""
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def flatten_paths(tree: dict, prefix: str = "") -> dict:
    flat = {}
    for k, v in tree.items():
        path = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            flat.update(flatten_paths(v, path))
        else:
            flat[path] = v
    return flat


def init_params(key: jax.Array, specs: dict) -> dict:
    """specs: flat path->ParamSpec. Returns nested param pytree."""
    paths = sorted(specs)
    keys = jax.random.split(key, max(len(paths), 1))
    flat = {p: _init_one(k, specs[p]) for p, k in zip(paths, keys)}
    return tree_from_flat(flat)


def abstract_params(specs: dict, shardings: Optional[dict] = None) -> dict:
    """ShapeDtypeStruct pytree (optionally with shardings attached)."""
    flat = {}
    for p, spec in specs.items():
        sh = None if shardings is None else shardings.get(p)
        flat[p] = jax.ShapeDtypeStruct(spec.shape, spec.dtype, sharding=sh)
    return tree_from_flat(flat)


def param_count(specs: dict) -> int:
    return sum(math.prod(s.shape) for s in specs.values())


def param_bytes(specs: dict) -> int:
    return sum(math.prod(s.shape) * jnp.dtype(s.dtype).itemsize
               for s in specs.values())
