from repro.quant.kv_scales import FP8_E4M3_MAX, calibrate_kv_scales
from repro.quant.formats import FORMATS, PAPER_FORMATS, Format, alpha, get_format
from repro.quant.qops import OpInfo, QuantContext, bgemm, linear, qeinsum
from repro.quant.qtensor import QTensor, compute_scale, dequantize, fake_quant, quantize

__all__ = [
    "FORMATS", "PAPER_FORMATS", "Format", "alpha", "get_format",
    "FP8_E4M3_MAX", "calibrate_kv_scales",
    "OpInfo", "QuantContext", "bgemm", "linear", "qeinsum",
    "QTensor", "compute_scale", "dequantize", "fake_quant", "quantize",
]
