"""Numerical format registry.

The paper models quantization noise of a floating-point format with ``m_f``
mantissa bits as relative uniform noise (eq. 15)::

    z~ ~ |z| * 2^{-m_f} * U[-1/2, 1/2]

whose per-element variance is ``|z|^2 * alpha_f`` with (eq. 16)::

    alpha_f = 2^{-2 m_f} / 12

The registry below carries, for every supported format: the mantissa width,
the JAX storage dtype (or None when the format is emulated), byte width, and
relative MAC throughput vs BF16 on the active hardware profile (used by the
theoretical time-gain metric, Sec. 2.3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

__all__ = [
    "Format",
    "FORMATS",
    "get_format",
    "alpha",
    "BF16",
    "FP8_E4M3",
    "FP8_E5M2",
    "FP16",
    "FP4_E2M1",
]


@dataclasses.dataclass(frozen=True)
class Format:
    """A floating-point numerical format usable for MP execution."""

    name: str
    mantissa_bits: int
    exponent_bits: int
    bytes: float  # storage bytes per element
    dtype: Optional[jnp.dtype]  # None => emulated (fake-quant only)
    # Max representable magnitude (for scale computation). None => no scaling
    # needed (the format is wide enough to hold bf16-ranged data directly).
    max_value: Optional[float]

    @property
    def alpha(self) -> float:
        """Per-element relative quantization-noise variance (eq. 16)."""
        return 2.0 ** (-2 * self.mantissa_bits) / 12.0

    @property
    def is_quantized(self) -> bool:
        return self.name != "bf16"

    def __str__(self) -> str:  # pragma: no cover - debugging nicety
        return self.name


BF16 = Format("bf16", mantissa_bits=8, exponent_bits=8, bytes=2, dtype=jnp.bfloat16,
              max_value=None)
# FP8-E4M3 per OCP / Gaudi2 / H100: max 448 (e4m3fn).
FP8_E4M3 = Format("fp8_e4m3", mantissa_bits=3, exponent_bits=4, bytes=1,
                  dtype=jnp.float8_e4m3fn, max_value=448.0)
FP8_E5M2 = Format("fp8_e5m2", mantissa_bits=2, exponent_bits=5, bytes=1,
                  dtype=jnp.float8_e5m2, max_value=57344.0)
FP16 = Format("fp16", mantissa_bits=10, exponent_bits=5, bytes=2, dtype=jnp.float16,
              max_value=65504.0)
# FP4-E2M1 (MXFP4 element type) — emulated fake-quant; max 6.0.
FP4_E2M1 = Format("fp4_e2m1", mantissa_bits=1, exponent_bits=2, bytes=0.5, dtype=None,
                  max_value=6.0)

FORMATS: dict[str, Format] = {
    f.name: f for f in (BF16, FP8_E4M3, FP8_E5M2, FP16, FP4_E2M1)
}

# The paper's experiment setting: F=2, {BF16, FP8-E4M3}.
PAPER_FORMATS = ("bf16", "fp8_e4m3")


def get_format(name: str) -> Format:
    try:
        return FORMATS[name]
    except KeyError as e:
        raise KeyError(
            f"unknown format {name!r}; known: {sorted(FORMATS)}") from e


def alpha(name: str) -> float:
    """alpha_f = 2^{-2 m_f} / 12 for a registered format name."""
    return get_format(name).alpha
