"""Per-layer KV-cache dequant scale calibration (scaled fp8 KV).

An fp8_e4m3 KV cache written *unscaled* clips any key/value whose magnitude
exceeds the format max (448) and wastes the format's dynamic range when a
layer's amax sits far below it. The serving read paths (fused kernel and
gather fallback) already carry per-tensor ``k_scale``/``v_scale`` dequant
multipliers; this module produces real values for them: run a calibration
prefill with a *bf16* cache, record each layer's per-entry amax at
cache-write time, and emit ``scale = amax / fp8_max`` so the write-side
divide (see :func:`repro.nn.layers.paged_update_attend`) maps every entry's
observed range onto the representable fp8 range and reads multiply it back.

Usage::

    scales = calibrate_kv_scales(model, params, calib_batches)
    serving_model = LM(dataclasses.replace(model.cfg,
                                           kv_cache_dtype="fp8_e4m3",
                                           kv_dequant_scales=scales))

The returned value is the per-layer tuple ``LMConfig.kv_dequant_scales``
accepts (one entry per layer: pair-tuple for attention/MLA layers, None for
SSM layers, whose state is not a paged KV cache).
"""
from __future__ import annotations

from typing import Iterable

import jax.numpy as jnp
import numpy as np

from repro.quant.qops import QuantContext

__all__ = ["calibrate_kv_scales", "FP8_E4M3_MAX"]

FP8_E4M3_MAX = 448.0


def _layer_kv_node(node: dict):
    """The attention-cache sub-dict of one layer's cache node, or None for
    SSM-only layers. Hybrid layers nest {"attn": ..., "mamba": ...}."""
    if not isinstance(node, dict):
        return None
    if "pos" in node:
        return node
    if "attn" in node and isinstance(node["attn"], dict) \
            and "pos" in node["attn"]:
        return node["attn"]
    return None


def calibrate_kv_scales(model, params, batches: Iterable, *,
                        fp8_max: float = FP8_E4M3_MAX) -> tuple:
    """Per-layer amax tracking at cache-write time -> dequant scales.

    Runs :meth:`LM.prefill` over ``batches`` on a clone of ``model`` with a
    bf16 cache (so the statistics are unquantized), reduces each layer's
    cache entries ("k"/"v", or "ckv"/"kr" for MLA) to their absolute max
    across all batches, and returns ``amax / fp8_max`` per entry. Entries
    that never exceed zero get unit scales. Requires the unrolled
    (non-``scan_layers``) layout — the same constraint as per-layer MP.
    """
    import dataclasses

    cfg = model.cfg
    if cfg.scan_layers:
        raise ValueError(
            "calibrate_kv_scales needs per-layer cache leaves; scan_layers "
            "stacks them — calibrate on the unrolled twin instead")
    bf16 = type(model)(dataclasses.replace(cfg, kv_cache_dtype="bfloat16",
                                           kv_dequant_scales=None))
    ctx = QuantContext()
    amax: dict = {}                              # (layer_key, entry) -> float
    for batch in batches:
        tokens = jnp.asarray(batch["tokens"] if isinstance(batch, dict)
                             else batch)
        B, T = tokens.shape
        caches = bf16.init_cache(B, T)
        _, caches = bf16.prefill(params, tokens, caches, ctx)
        for lk, node in caches.items():
            kv = _layer_kv_node(node)
            if kv is None:
                continue
            for name, leaf in kv.items():
                if name == "pos":
                    continue
                m = float(jnp.max(jnp.abs(leaf.astype(jnp.float32))))
                key = (lk, name)
                amax[key] = max(amax.get(key, 0.0), m)

    out = []
    for i in range(cfg.n_layers):
        lk = f"layers/{i}"
        entries = sorted(n for (k, n) in amax if k == lk)
        if not entries:
            out.append(None)
            continue
        pairs = []
        for name in entries:
            m = amax[(lk, name)]
            s = m / float(fp8_max) if m > 0.0 else 1.0
            pairs.append((name, float(np.float32(s))))
        out.append(tuple(pairs))
    return tuple(out)
