"""Quantizable op entry points.

Every linear operation the paper can quantize — standard linear layers
(``L_lin``) and batched GEMMs inside attention (``L_BGEMM``) — is funneled
through :func:`qeinsum`. A :class:`QuantContext` selects the execution mode:

* ``plain``   — high-precision (BF16) execution.
* ``mp``      — execute under a mixed-precision assignment: operands of op
                ``name`` are (fake- or real-) quantized to the assigned format.
* ``probe``   — sensitivity calibration (Sec. 2.2): operands receive additive
                zero probes ``z + p`` and the unperturbed operands are captured
                so the caller can evaluate ``s_l = ||z (.) dg/dz||^2`` (eq. 19).

When ``ctx.registry`` is a list, every op also records an :class:`OpInfo`
(shapes, MACs, weight element count) — used by the partitioner and the
performance metrics. Tracing a model under ``jax.eval_shape`` with a registry
thus yields the full quantizable-op inventory without allocating anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.quant import qtensor
from repro.quant.formats import get_format

__all__ = ["QuantContext", "OpInfo", "qeinsum", "linear", "bgemm"]

KIND_LINEAR = "linear"   # rhs is a weight tensor (persistent)
KIND_BGEMM = "bgemm"     # both operands are activations


@dataclasses.dataclass(frozen=True)
class OpInfo:
    """Static description of one quantizable op occurrence."""

    name: str
    kind: str                 # linear | bgemm
    spec: str                 # einsum spec
    lhs_shape: tuple
    rhs_shape: tuple
    out_shape: tuple
    macs: int                 # multiply-accumulates for one evaluation
    weight_elems: int         # persistent parameter elements (0 for bgemm)


@dataclasses.dataclass
class QuantContext:
    """Carries the execution mode through a model's apply function.

    Only array-valued fields (``probes``) participate in tracing; the mode and
    the MP assignment are static (bake them into the jitted closure).
    """

    mode: str = "plain"                       # plain | mp | probe
    mp: Optional[dict] = None                 # op name -> format name
    impl: str = "simulate"                    # simulate | native | pallas
    probes: Optional[dict] = None             # op name -> (p_lhs, p_rhs)
    captures: Optional[dict] = None           # out: op name -> (lhs, rhs)
    registry: Optional[list] = None           # out: list[OpInfo]
    scales: Optional[dict] = None             # op name -> (s_lhs, s_rhs) calibrated
    default_format: str = "bf16"
    # When set (serving: 0), activation operands get one dynamic quant scale
    # per slice of this axis instead of one per tensor. Per-sequence scales
    # decouple co-batched requests — a prerequisite for continuous batching,
    # where greedy tokens must not depend on which other requests share the
    # decode batch. Weights keep per-tensor scales (batch-invariant anyway).
    act_scale_axis: Optional[int] = None
    # Serving default since the chunked-prefill refactor: per-*token* scales.
    # Each activation operand keeps every batch/token einsum axis and reduces
    # only feature/head axes, so a token's quantization grid depends on that
    # token's features alone. Strictly finer than per-sequence scales, this
    # keeps greedy tokens independent of (a) which requests share the batch,
    # (b) how a prompt is split into prefill chunks, and (c) bucket padding —
    # the invariances the chunked/bucketed prefill parity tests pin down.
    # It also gives expert-grouped GEMMs per-(expert, token) scales, closing
    # most of the MoE batch-composition caveat from PR 1.
    act_scale_token: bool = False

    def format_for(self, name: str) -> str:
        if self.mp is None:
            return self.default_format
        return self.mp.get(name, self.default_format)


def _einsum_macs(spec: str, lhs_shape, rhs_shape) -> int:
    """MAC count of an einsum: product of all distinct dimension sizes."""
    ins, out = spec.split("->")
    a, b = ins.split(",")
    dims: dict[str, int] = {}
    for labels, shape in ((a, lhs_shape), (b, rhs_shape)):
        for ch, s in zip(labels, shape):
            dims[ch] = int(s)
    return int(math.prod(dims.values()))


def _maybe_register(ctx: QuantContext, name: str, kind: str, spec: str,
                    lhs, rhs, out) -> None:
    if ctx.registry is None:
        return
    weight_elems = int(math.prod(rhs.shape)) if kind == KIND_LINEAR else 0
    ctx.registry.append(OpInfo(
        name=name, kind=kind, spec=spec,
        lhs_shape=tuple(lhs.shape), rhs_shape=tuple(rhs.shape),
        out_shape=tuple(out.shape),
        macs=_einsum_macs(spec, lhs.shape, rhs.shape),
        weight_elems=weight_elems,
    ))


def _quantize_operand(x: jax.Array, fmt_name: str, impl: str,
                      scale: Optional[jax.Array],
                      axis: Optional[tuple] = None) -> jax.Array:
    """Return the operand as it would be consumed by the MP matmul."""
    fmt = get_format(fmt_name)
    if not fmt.is_quantized:
        return x
    if impl == "native" and fmt.dtype is not None:
        q = qtensor.quantize(x, fmt_name, axis=axis, scale=scale)
        # Native path: dequantize scales are folded into the output; for
        # simplicity (and exactness of the noise model) we dequantize to the
        # compute dtype here — XLA fuses the rescale into the dot epilogue.
        return q.dequantize(x.dtype)
    return qtensor.fake_quant(x, fmt_name, axis=axis, scale=scale)


# Einsum labels that index batch or token positions in this codebase's op
# specs (layers/mamba/moe): B/T/S (batch, q-tokens, k-tokens), E/N (expert,
# token-within-expert) and lowercase b/c/q/k (SSD batch, chunk, within-chunk
# positions). Per-token quantization keeps these axes and reduces the rest
# (heads, head_dim, features), making every token's scale a function of that
# token's own features only.
#
# CONTRACT: these letters are reserved for batch/token axes across every
# qeinsum/bgemm spec in the repo. A new op spec that reuses one of them for
# a feature/head/state axis would silently get per-(token, feature) scales
# under the serving policy — pick a different letter (free: A F I J L M O P
# Q R U W X Y Z and most lowercase), and extend the serving parity matrix in
# tests/test_serve.py if the op runs at serve time.
_TOKEN_LABELS = frozenset("BTSENbcqk")


def _token_scale_axes(labels: str) -> tuple:
    """Reduce axes for an activation operand's per-token scale. May be the
    empty tuple (an operand whose axes are all batch/token labels then gets
    a per-element scale) — never None: falling back to a per-tensor amax
    would couple tokens through the shared scale, the exact failure mode
    token granularity exists to prevent."""
    return tuple(i for i, ch in enumerate(labels) if ch not in _TOKEN_LABELS)


def act_quant_axes(ctx: QuantContext, ndim: int) -> Optional[tuple]:
    """Scale-reduction axes for an activation operand: everything except the
    per-sequence axis (None -> per-tensor scale). Token-granular contexts
    (``act_scale_token``) are handled in :func:`qeinsum` via the einsum spec;
    callers without a spec (flash attention) special-case it themselves."""
    if ctx.act_scale_axis is None:
        return None
    keep = ctx.act_scale_axis % ndim
    return tuple(a for a in range(ndim) if a != keep)


def qeinsum(ctx: QuantContext, name: str, spec: str, lhs: jax.Array,
            rhs: jax.Array, kind: str = KIND_LINEAR,
            accum_dtype=jnp.float32) -> jax.Array:
    """Quantizable einsum — the single entry point for L_lin and L_BGEMM."""
    out_dtype = lhs.dtype

    if ctx.mode == "probe":
        if ctx.probes is not None and name in ctx.probes:
            p_lhs, p_rhs = ctx.probes[name]
            if ctx.captures is not None:
                ctx.captures[name] = (lhs, rhs)
            lhs = lhs + p_lhs.astype(lhs.dtype)
            rhs = rhs + p_rhs.astype(rhs.dtype)
    elif ctx.mode == "mp":
        fmt_name = ctx.format_for(name)
        if get_format(fmt_name).is_quantized:
            s_lhs = s_rhs = None
            if ctx.scales is not None and name in ctx.scales:
                s_lhs, s_rhs = ctx.scales[name]
            if ctx.impl == "pallas" and kind == KIND_LINEAR and lhs.ndim == 2:
                from repro.kernels import ops as kops  # lazy: optional dep
                return kops.fp8_linear(lhs, rhs, spec=spec, fmt_name=fmt_name,
                                       out_dtype=out_dtype)
            # activations may use per-sequence or per-token scales (serving);
            # the weight of a linear op is batch-invariant and keeps a
            # per-tensor scale
            if ctx.act_scale_token:
                a_l, b_l = spec.split("->")[0].split(",")
                lhs_axes = _token_scale_axes(a_l)
                rhs_axes = _token_scale_axes(b_l)
            else:
                lhs_axes = act_quant_axes(ctx, lhs.ndim)
                rhs_axes = act_quant_axes(ctx, rhs.ndim)
            lhs = _quantize_operand(lhs, fmt_name, ctx.impl, s_lhs, lhs_axes)
            rhs = _quantize_operand(rhs, fmt_name, ctx.impl, s_rhs,
                                    rhs_axes if kind == KIND_BGEMM else None)

    out = jnp.einsum(spec, lhs, rhs, preferred_element_type=accum_dtype)
    out = out.astype(out_dtype)
    _maybe_register(ctx, name, kind, spec, lhs, rhs, out)
    return out


def linear(ctx: QuantContext, name: str, x: jax.Array, w: jax.Array,
           b: Optional[jax.Array] = None) -> jax.Array:
    """Standard linear layer y = x @ w^T (+ b); w: (K, C) per eq. (8).

    ``x`` may have arbitrary leading batch dims; the last dim contracts.
    ``w`` may carry leading batch/expert dims (grouped/expert GEMM), which
    must align with the leading dims of ``x``.
    """
    if w.dtype != x.dtype and jnp.dtype(w.dtype).itemsize == 1:
        w = w.astype(x.dtype)  # fp8-stored weights: dequant at use
    if w.ndim == 2:
        xl = "BC" if x.ndim == 2 else "BSC" if x.ndim == 3 else None
        if xl is None:  # flatten exotic ranks
            lead = x.shape[:-1]
            y = linear(ctx, name, x.reshape(-1, x.shape[-1]), w, b)
            return y.reshape(*lead, w.shape[0])
        spec = f"{xl},KC->{xl[:-1]}K"
    elif w.ndim == 3 and x.ndim == 3:
        spec = "ENC,EKC->ENK"  # expert-grouped GEMM
    else:
        raise ValueError(f"unsupported linear ranks x={x.shape} w={w.shape}")
    y = qeinsum(ctx, name, spec, x, w, kind=KIND_LINEAR)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def bgemm(ctx: QuantContext, name: str, spec: str, a: jax.Array,
          b: jax.Array) -> jax.Array:
    """Batched GEMM between two activations (qk_matmul / av_matmul / SSD)."""
    return qeinsum(ctx, name, spec, a, b, kind=KIND_BGEMM)
