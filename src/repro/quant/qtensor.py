"""Scaled casting between BF16 and low-precision formats.

Two execution paths:

* ``cast_real``   — actually stores the tensor in the target dtype (fp8/fp16)
                    with a per-tensor (or per-channel) scale. This is what a
                    TPU deployment executes (MXU consumes fp8 operands).
* ``fake_quant``  — quantize-dequantize in the source dtype. Numerically it
                    produces the same values as cast_real followed by dequant
                    and is used on CPU for calibration/benchmarks and for
                    emulated formats (fp4).

Scales follow the amax convention used by Intel Neural Compressor / TE:
``scale = max_value / amax`` so that ``x * scale`` fits the representable
range; dequantization multiplies by ``1/scale``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.formats import Format, get_format

__all__ = ["QTensor", "compute_scale", "quantize", "dequantize", "fake_quant"]


@dataclasses.dataclass
class QTensor:
    """A quantized tensor: low-precision payload + dequant scale.

    ``data * scale_inv`` reconstructs (an approximation of) the original.
    Registered as a pytree so it can flow through jit.
    """

    data: jax.Array
    scale_inv: jax.Array  # scalar or per-channel, broadcastable to data
    fmt_name: str

    @property
    def fmt(self) -> Format:
        return get_format(self.fmt_name)

    def dequantize(self, dtype=jnp.bfloat16) -> jax.Array:
        return (self.data.astype(jnp.float32) * self.scale_inv).astype(dtype)


def _qtensor_flatten(q):
    return (q.data, q.scale_inv), q.fmt_name


def _qtensor_unflatten(fmt_name, children):
    return QTensor(children[0], children[1], fmt_name)


jax.tree_util.register_pytree_node(QTensor, _qtensor_flatten, _qtensor_unflatten)


def compute_scale(x: jax.Array, fmt: Format, axis: Optional[tuple] = None,
                  margin: float = 1.0) -> jax.Array:
    """amax-based scale: ``scale = fmt.max_value / amax``.

    axis=None -> per-tensor scalar scale; otherwise reduce over ``axis`` for
    per-channel scales. ``margin`` (<=1) backs off from the format max.
    """
    if fmt.max_value is None:
        shape = () if axis is None else tuple(
            1 if a in _norm_axes(axis, x.ndim) else s
            for a, s in enumerate(x.shape))
        return jnp.ones(shape, jnp.float32)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=axis is not None)
    amax = jnp.maximum(amax, 1e-12)
    return (fmt.max_value * margin) / amax


def _norm_axes(axis, ndim):
    if axis is None:
        return ()
    if isinstance(axis, int):
        axis = (axis,)
    return tuple(a % ndim for a in axis)


def quantize(x: jax.Array, fmt_name: str, axis: Optional[tuple] = None,
             scale: Optional[jax.Array] = None) -> QTensor:
    """Cast ``x`` into the target format with amax scaling (real storage)."""
    fmt = get_format(fmt_name)
    if scale is None:
        scale = compute_scale(x, fmt, axis)
    xf = x.astype(jnp.float32) * scale
    if fmt.dtype is not None:
        data = xf.astype(fmt.dtype)
    else:  # emulated format: store the rounded values in bf16
        data = _round_to_format(xf, fmt).astype(jnp.bfloat16)
    return QTensor(data=data, scale_inv=(1.0 / scale).astype(jnp.float32),
                   fmt_name=fmt_name)


def dequantize(q: QTensor, dtype=jnp.bfloat16) -> jax.Array:
    return q.dequantize(dtype)


def fake_quant(x: jax.Array, fmt_name: str, axis: Optional[tuple] = None,
               scale: Optional[jax.Array] = None) -> jax.Array:
    """Quantize-dequantize; output has the dtype of ``x``.

    For ``bf16`` this is the identity (inputs are already bf16).
    """
    fmt = get_format(fmt_name)
    if fmt.name == "bf16":
        return x
    q = quantize(x, fmt_name, axis=axis, scale=scale)
    return q.dequantize(x.dtype)


def _round_to_format(xf: jax.Array, fmt: Format) -> jax.Array:
    """Round fp32 values to an emulated mini-float grid (RTNE, saturating).

    Handles formats without a native JAX dtype (e.g. fp4_e2m1).
    """
    m = fmt.mantissa_bits
    # Exponent range of an IEEE-like minifloat with bias 2^(e-1)-1.
    bias = 2 ** (fmt.exponent_bits - 1) - 1
    emin = 1 - bias  # minimum normal exponent
    absx = jnp.abs(xf)
    sign = jnp.sign(xf)
    # Clamp to max, flush below half the smallest subnormal to zero.
    absx = jnp.minimum(absx, fmt.max_value)
    exp = jnp.floor(jnp.log2(jnp.maximum(absx, 1e-38)))
    exp = jnp.maximum(exp, emin)  # subnormal region shares emin spacing
    step = jnp.exp2(exp - m)
    rounded = jnp.round(absx / step) * step
    rounded = jnp.where(absx == 0.0, 0.0, rounded)
    return sign * jnp.minimum(rounded, fmt.max_value)
