from repro.serve.adaptive import AdaptiveMPController, NumericalGuardrail
from repro.serve.cache_pool import (CachePool, PagedCachePool,
                                    dense_slot_bytes, paged_block_bytes,
                                    paged_slot_bytes)
from repro.serve.engine import (ContinuousBatchingEngine, GenResult,
                                ServeEngine, ServeSummary, prefill_bucket)
from repro.serve.faults import (FAULT_KINDS, FaultInjector, FaultSpec,
                                InjectedFault)
from repro.serve.parallel import (make_serving_layout, shard_cache_tree,
                                  shard_serving_params)
from repro.serve.scheduler import Request, RequestResult, Scheduler

__all__ = ["AdaptiveMPController", "CachePool",
           "ContinuousBatchingEngine", "FAULT_KINDS", "FaultInjector",
           "FaultSpec", "GenResult", "InjectedFault", "NumericalGuardrail",
           "PagedCachePool", "Request", "RequestResult", "Scheduler",
           "ServeEngine", "ServeSummary", "dense_slot_bytes",
           "make_serving_layout", "paged_block_bytes", "paged_slot_bytes",
           "prefill_bucket", "shard_cache_tree", "shard_serving_params"]
