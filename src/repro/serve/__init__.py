from repro.serve.cache_pool import CachePool
from repro.serve.engine import (ContinuousBatchingEngine, GenResult,
                                ServeEngine, ServeSummary)
from repro.serve.scheduler import Request, RequestResult, Scheduler

__all__ = ["CachePool", "ContinuousBatchingEngine", "GenResult", "Request",
           "RequestResult", "Scheduler", "ServeEngine", "ServeSummary"]
