"""Load-adaptive mixed precision: close the solver <-> scheduler loop.

The paper's pipeline solves one MP plan offline for a fixed loss-MSE budget
``tau`` and serves it forever. This module makes the budget *load-adaptive*:
an :class:`AdaptiveMPController` consumes the continuous engine's live
counters (queue depth, blocked admissions, block occupancy, decode-stall
p99) every ``every`` engine ticks and walks a ladder of pre-solved plans —
escalating to a more aggressive quantization (larger ``tau``: looser MSE
constraint, bigger gained time, cheaper steps) when the queue grows, and
restoring toward the base plan as it drains.

Stability machinery, in controller rather than engine code so it is
unit-testable in isolation:

* **hysteresis bands** — escalation triggers at the *high* watermarks,
  restoration only once every signal is below the *low* watermarks; the gap
  between them absorbs load noise so the controller cannot chatter between
  two levels on a flat workload;
* **min-dwell** — after any swap, no further swap for ``dwell`` ticks, a
  hard upper bound on swap frequency regardless of watermark tuning;
* **step-boundary application** — ``observe`` is *pure decision*: it
  returns the new plan (or None) and the engine applies it between compiled
  steps through the ``get_serving_step`` memo, whose key includes the MP
  assignment. A swap is therefore a dispatch switch to an already- (or
  lazily-) compiled program, never a mid-step recompile, and with the
  controller disabled (or never firing) greedy tokens under the fixed base
  plan are bit-identical to a plain engine.

Each ladder level's plan is solved once from the calibration bundle
(:meth:`CalibrationBundle.solve` is pure NumPy) and memoized; the solve uses
the bundle's measured wall-clock gain table when one is persisted
(``gain_tier == "measured"``), falling back to the roofline model otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["AdaptiveMPController", "NumericalGuardrail"]


@dataclasses.dataclass
class AdaptiveMPController:
    """Walks a tau ladder over a calibration bundle under load feedback.

    ``taus`` must be ascending: index 0 is the base (least aggressive)
    plan, the last entry the most aggressive fallback. ``observe`` is
    called once per engine tick with cumulative counters; it evaluates only
    every ``every`` ticks, never swaps within ``dwell`` ticks of the last
    swap, and moves at most one ladder level per evaluation (so a load
    spike ramps through the intermediate plans instead of jumping to the
    floor).

    Watermarks: escalate when ``queue_depth >= queue_high`` or ``occupancy
    >= occ_high`` or any admission was blocked since the last evaluation or
    ``stall_p99 >= stall_high_s``; restore when ``queue_depth <=
    queue_low`` *and* ``occupancy <= occ_low`` *and* nothing was blocked
    *and* ``stall_p99 < stall_high_s``. Between the bands the level holds.
    """

    bundle: object                       # CalibrationBundle
    taus: Sequence[float]
    objective: str = "ET"
    every: int = 4                       # evaluation cadence, engine ticks
    dwell: int = 16                      # min ticks between swaps
    queue_high: int = 4
    queue_low: int = 0
    occ_high: float = 0.90
    occ_low: float = 0.50
    stall_high_s: float = float("inf")

    def __post_init__(self):
        self.taus = tuple(float(t) for t in self.taus)
        if not self.taus:
            raise ValueError("need at least one tau level")
        if list(self.taus) != sorted(self.taus):
            raise ValueError(f"taus must ascend (base plan first): "
                             f"{self.taus}")
        if self.every < 1 or self.dwell < 0:
            raise ValueError((self.every, self.dwell))
        if not (self.queue_low <= self.queue_high
                and self.occ_low <= self.occ_high):
            raise ValueError("hysteresis bands must satisfy low <= high")
        self.level = 0
        self.downshifts = 0              # swaps toward more aggressive
        self.restores = 0                # swaps back toward the base plan
        self.guardrail_restores = 0      # forced restores (numerical breach)
        self.history: list = []          # (tick, level, tau) per swap
        self._plans: dict = {}
        self._last_eval: Optional[int] = None
        self._last_swap: Optional[int] = None
        self._blocked_seen = 0

    @classmethod
    def from_bundle(cls, bundle, base_tau: float, *, n_levels: int = 3,
                    factor: float = 2.0, **kw) -> "AdaptiveMPController":
        """Geometric tau ladder: ``base_tau * factor**i`` for i < n_levels.
        Doubling tau quadruples the MSE budget (budget = tau^2 * E[g^2]),
        which in practice unlocks the next block of quantizable ops."""
        assert n_levels >= 1 and factor > 1.0, (n_levels, factor)
        taus = [base_tau * factor ** i for i in range(n_levels)]
        return cls(bundle=bundle, taus=taus, **kw)

    # ------------------------------------------------------------------
    @property
    def tau(self) -> float:
        return self.taus[self.level]

    def plan_for(self, level: int):
        """The (memoized) solved plan for a ladder level."""
        if level not in self._plans:
            self._plans[level] = self.bundle.solve(
                tau=self.taus[level], objective=self.objective)
        return self._plans[level]

    @property
    def plan(self):
        return self.plan_for(self.level)

    # ------------------------------------------------------------------
    def observe(self, now: int, *, queue_depth: int, blocked: int,
                occupancy: float, stall_p99: float = 0.0):
        """One engine tick's counters in; a plan to swap to out (or None).

        ``now`` is the engine's deterministic step clock; ``blocked`` is the
        scheduler's *cumulative* blocked-admission count (the controller
        diffs it across evaluations, so skipped ticks lose no signal);
        ``occupancy`` is the fraction of KV capacity in use. Re-observing
        the same tick is a no-op — the engine consults exactly once per
        tick, at the step boundary before admission."""
        if self._last_eval is not None and now < self._last_eval:
            # the engine's step clock restarted (a new serve() drain): the
            # cadence/dwell anchors reset; the ladder level carries over
            self._last_eval = None
            self._last_swap = None
            self._blocked_seen = 0
        if blocked < self._blocked_seen:    # fresh Scheduler, fresh counter
            self._blocked_seen = 0
        if self._last_eval is not None and now - self._last_eval < self.every:
            return None
        self._last_eval = now
        blocked_delta = blocked - self._blocked_seen
        self._blocked_seen = blocked
        if self._last_swap is not None and now - self._last_swap < self.dwell:
            return None
        hot = (queue_depth >= self.queue_high
               or occupancy >= self.occ_high
               or blocked_delta > 0
               or stall_p99 >= self.stall_high_s)
        cool = (queue_depth <= self.queue_low
                and occupancy <= self.occ_low
                and blocked_delta == 0
                and stall_p99 < self.stall_high_s)
        if hot and self.level < len(self.taus) - 1:
            self.level += 1
            self.downshifts += 1
        elif cool and self.level > 0:
            self.level -= 1
            self.restores += 1
        else:
            return None
        self._last_swap = now
        self.history.append((now, self.level, self.tau))
        return self.plan

    def force_restore(self, now: int):
        """Guardrail override: jump straight back to the level-0 base plan,
        bypassing cadence, dwell and the one-level-per-evaluation walk — a
        measured numerical breach outranks load smoothing. Returns the base
        plan (the engine applies it at the step boundary like any other
        swap). Idempotent at level 0."""
        if self.level != 0:
            self.level = 0
            self.restores += 1
            self.history.append((now, self.level, self.tau))
        self.guardrail_restores += 1
        self._last_swap = now
        return self.plan


@dataclasses.dataclass
class NumericalGuardrail:
    """Tau-anchored runtime check of the solver's loss-MSE bound.

    The IP solver guarantees *predicted* loss-MSE <= ``budget = tau^2 *
    E[g^2]`` (the paper's eq. 23 constraint) — on the calibration set. This
    guardrail closes the loop at serve time: every ``every`` decode steps
    the engine runs one extra *high-precision shadow step* over the same
    inputs (same caches, same tokens; its cache writes are discarded),
    measures the fp32 logit-MSE between the active plan's logits and the
    shadow's for one sampled live row, and compares it against ``margin *
    budget``. ``margin`` absorbs the gap between the calibration-set
    loss-MSE the budget bounds and the single-row logit-MSE actually
    measured (the paper's linearization ``d = s_l * alpha_f`` ties the two
    scales); breaches beyond ``max_breaches`` force a restore to the base
    plan — through :meth:`AdaptiveMPController.force_restore` when a
    controller is attached, or by dropping to the unquantized plan
    otherwise.

    Cost model: one extra decode step plus one blocking scalar readback per
    ``every`` steps — amortized overhead ~``1/every``, gated < 2% in the
    ``serve_throughput`` benchmark leg. Once restored (quantization off)
    the shadow equals the active step, so the engine stops checking and the
    overhead drops to zero.
    """

    every: int = 16                  # shadow cadence, decode steps
    margin: float = 4.0              # budget multiplier before a breach
    max_breaches: int = 1            # breaches tolerated before restoring
    budget: Optional[float] = None   # explicit loss-MSE budget override

    def __post_init__(self):
        if self.every < 1 or self.margin <= 0 or self.max_breaches < 1:
            raise ValueError((self.every, self.margin, self.max_breaches))
        self.checks = 0
        self.breaches = 0
        self.last_mse: Optional[float] = None
        self.restored_at: Optional[int] = None
        self.history: list = []      # (tick, mse, budget) per breach

    def budget_for(self, plan) -> Optional[float]:
        """The loss-MSE budget to hold ``plan`` to: the explicit override,
        else the plan's own solved ``budget`` (tau^2 E[g^2]), else its
        ``predicted_loss_mse``. None (no budget derivable — e.g. a raw
        assignment dict) disables breach detection but still records MSE."""
        if self.budget is not None:
            return self.budget
        for attr in ("budget", "predicted_loss_mse"):
            v = getattr(plan, attr, None)
            if v is not None:
                return float(v)
        return None

    def observe_mse(self, now: int, mse: float,
                    budget: Optional[float]) -> bool:
        """Record one shadow measurement; True means *restore now*."""
        self.checks += 1
        self.last_mse = float(mse)
        if budget is None or not (mse > self.margin * budget):
            return False
        self.breaches += 1
        self.history.append((int(now), float(mse), float(budget)))
        if self.breaches >= self.max_breaches and self.restored_at is None:
            self.restored_at = int(now)
            return True
        return False
