"""KV/SSM cache pools for continuous batching: dense slots and paged blocks.

:class:`CachePool` (dense) owns one device cache tree whose leading (batch)
axis is the slot axis: ``n_slots`` independent sequences decode together in a
single compiled step, each slot a monolithic ``max_len`` ring. HBM scales
with ``n_slots * max_len`` regardless of live tokens.

:class:`PagedCachePool` (vLLM-style) replaces the per-slot rings with a
shared store of fixed-size blocks: attention K/V lives in block-major arrays
``(n_blocks, block_size, ...)``, each slot maps its logical pages to physical
blocks through a host-side block table, and blocks are allocated on demand as
decode crosses block boundaries — HBM scales with *live tokens*, so an MP
plan's fp8 ``kv_cache_dtype`` savings buy proportionally more concurrent
slots. Admission reserves a request's worst-case block count (prompt +
``max_new_tokens - 1`` writes), which makes mid-decode allocation infallible
while materializing blocks lazily; :meth:`can_admit` returning False is the
scheduler's backpressure signal. Physical block 0 is never allocated: it is
the trash block that absorbs writes from vacant decode rows (block-table
entries of -1 clamp to 0 inside the kernel). SSM state has no sequence axis
and stays slot-major.

All allocation is host-side free lists; device traffic goes through
:meth:`insert` (one jitted scatter, traced over slot/block ids).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["CachePool", "PagedCachePool", "dense_slot_bytes",
           "paged_block_bytes", "paged_slot_bytes"]


def dense_slot_bytes(model, max_len: int) -> int:
    """HBM bytes one dense cache slot (KV rings + SSM state) pins at
    ``max_len`` — the dense baseline cost of a slot whether or not it holds
    live tokens."""
    from repro.nn.spec import param_bytes
    return param_bytes(model.cache_specs(1, max_len))


def paged_block_bytes(model, block_size: int) -> int:
    """HBM bytes one KV block adds across all layers (the marginal cost of
    ``block_size`` live tokens under paging)."""
    from repro.nn.spec import param_bytes
    return (param_bytes(model.paged_cache_specs(1, 2, block_size))
            - param_bytes(model.paged_cache_specs(1, 1, block_size)))


def paged_slot_bytes(model, block_size: int) -> int:
    """HBM bytes one *slot* pins under paging regardless of live tokens:
    the slot-major leaves (SSM state on mamba/hybrid archs; zero for pure
    attention). Counted so paged-vs-dense comparisons stay symmetric."""
    from repro.nn.spec import param_bytes
    return (param_bytes(model.paged_cache_specs(2, 1, block_size))
            - param_bytes(model.paged_cache_specs(1, 1, block_size)))


@jax.jit
def _scatter_slot(pool: dict, one: dict, slot: jax.Array) -> dict:
    def upd(pl, ol):
        start = (slot,) + (0,) * (pl.ndim - 1)
        return jax.lax.dynamic_update_slice(pl, ol.astype(pl.dtype), start)
    return jax.tree.map(upd, pool, one)


class CachePool:
    """``n_slots`` x ``max_len`` dense KV/SSM cache slots for one model.

    A freshly prefilled single-request cache (batch=1) is scattered into a
    slot with one jitted ``dynamic_update_slice`` per leaf; because the
    insert overwrites the *entire* slot row — including the ring-buffer
    ``pos`` entries that gate the attention mask — stale K/V from the slot's
    previous occupant can never leak into a new request.
    """

    def __init__(self, model, n_slots: int, max_len: int):
        assert n_slots >= 1 and max_len >= 1, (n_slots, max_len)
        self.n_slots = n_slots
        self.max_len = max_len
        self.caches = model.init_cache(n_slots, max_len)
        self._free = list(range(n_slots - 1, -1, -1))  # pop() yields slot 0 first

    # ---- host-side slot accounting ----
    @property
    def n_free(self) -> int:
        return len(self._free)

    # uniform pool interface (shared with PagedCachePool)
    @property
    def n_free_slots(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """Claim a free slot index; raises RuntimeError when the pool is full."""
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop()

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self._free.append(slot)

    def free_slot(self, slot: int) -> None:
        self.free(slot)

    # ---- device-side slot contents ----
    def insert(self, slot: int, request_cache: dict) -> None:
        """Scatter a batch=1 cache tree into ``slot`` (overwrites the row).
        Not on the engine's serving path since the chunked-prefill rewrite
        (dense prefill now writes the pool row in place); kept as the
        generic cache-injection API and covered by the pool tests."""
        self.caches = _scatter_slot(self.caches, request_cache,
                                    jnp.asarray(slot, jnp.int32))


class PagedCachePool:
    """Paged KV storage: ``n_blocks`` blocks of ``block_size`` tokens shared
    by ``n_slots`` decode rows through per-slot block tables.

    Invariants the attention kernel relies on (see ``nn/layers.py``):

    * a block is owned by at most one slot at a time (block 0 by nobody — it
      is the trash sink for vacant rows);
    * a slot's pages are allocated in logical order and written contiguously,
      so every logical position <= the slot's current write position holds
      that slot's own fresh data and the causal mask alone separates live
      keys from stale block contents — freed blocks need no device-side
      scrubbing before reuse.

    Admission accounting: :meth:`alloc_slot` *reserves* the request's
    worst-case block count without materializing it; :meth:`ensure_block`
    then draws on the reservation as decode crosses block boundaries.
    ``can_admit`` is False while free-minus-reserved can't cover a new
    request — the backpressure signal the scheduler turns into head-of-line
    queueing.
    """

    def __init__(self, model, n_slots: int, max_len: int,
                 block_size: int = 16, n_blocks=None):
        assert n_slots >= 1 and max_len >= 1 and block_size >= 1
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks = -(-max_len // block_size)     # table width per slot
        if n_blocks is None:
            # worst case: every slot decodes to max_len (same HBM as dense,
            # modulo block rounding); size it tighter to realize the win
            n_blocks = 1 + n_slots * self.max_blocks
        assert n_blocks >= 2, "need at least the trash block plus one"
        self.n_blocks = n_blocks
        self.caches = model.init_paged_cache(n_slots, n_blocks, block_size)
        self._insert_fn = jax.jit(model.paged_insert)
        self._free_blocks = list(range(n_blocks - 1, 0, -1))  # 0 = trash
        self._free_slots = list(range(n_slots - 1, -1, -1))
        self._reserved = 0                  # promised, not yet materialized
        self._slot_reserve: dict = {}       # slot -> outstanding reservation
        self._slot_blocks: dict = {}        # slot -> [owned block ids]
        self.block_tables = np.full((n_slots, self.max_blocks), -1, np.int32)

    # ---- budget / accounting ----
    @property
    def n_free_slots(self) -> int:
        return len(self._free_slots)

    @property
    def n_free_blocks(self) -> int:
        return len(self._free_blocks)

    @property
    def blocks_in_use(self) -> int:
        return (self.n_blocks - 1) - len(self._free_blocks)

    def blocks_for(self, n_tokens: int) -> int:
        return max(-(-n_tokens // self.block_size), 1)

    def blocks_for_request(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case blocks a request can touch: the prompt plus one KV
        write per decode step (the last generated token is never written)."""
        return self.blocks_for(prompt_len + max(max_new_tokens - 1, 0))

    def can_admit(self, prompt_len: int, max_new_tokens: int) -> bool:
        need = self.blocks_for_request(prompt_len, max_new_tokens)
        return (bool(self._free_slots)
                and need <= len(self._free_blocks) - self._reserved)

    # ---- slot lifecycle ----
    def alloc_slot(self, prompt_len: int, max_new_tokens: int) -> int:
        """Claim a slot and reserve the request's worst-case block budget."""
        need = self.blocks_for_request(prompt_len, max_new_tokens)
        if need > self.n_blocks - 1:
            raise ValueError(
                f"request needs {need} blocks but the pool only has "
                f"{self.n_blocks - 1} allocatable blocks")
        if not self.can_admit(prompt_len, max_new_tokens):
            raise RuntimeError("paged cache pool exhausted")
        slot = self._free_slots.pop()
        self._reserved += need
        self._slot_reserve[slot] = need
        self._slot_blocks[slot] = []
        return slot

    def free_slot(self, slot: int) -> None:
        """Return the slot, its blocks, and any unused reservation."""
        assert slot not in self._free_slots, slot
        self._free_blocks.extend(reversed(self._slot_blocks.pop(slot, [])))
        self._reserved -= self._slot_reserve.pop(slot, 0)
        self.block_tables[slot] = -1
        self._free_slots.append(slot)

    def _alloc_block(self, slot: int) -> int:
        if not self._free_blocks:
            raise RuntimeError("paged cache pool out of blocks")
        blk = self._free_blocks.pop()
        if self._slot_reserve.get(slot, 0) > 0:
            self._slot_reserve[slot] -= 1
            self._reserved -= 1
        self._slot_blocks[slot].append(blk)
        return blk

    def ensure_block(self, slot: int, pos: int) -> None:
        """Alloc-on-demand: materialize the page for write position ``pos``
        when decode crosses a block boundary. Covered by the admission-time
        reservation, so it cannot fail for an admitted request."""
        page, off = divmod(int(pos), self.block_size)
        if off == 0 and self.block_tables[slot, page] < 0:
            self.block_tables[slot, page] = self._alloc_block(slot)

    def ensure_range(self, slot: int, start: int, end: int) -> None:
        """Materialize every page covering logical positions [start, end) —
        chunked prefill's incremental reservation: blocks appear chunk by
        chunk (each drawing on the admission-time reservation) instead of
        the whole prompt's worth at once, so blocks a later chunk will fill
        stay in the free pool until that chunk actually runs."""
        assert 0 <= start < end, (start, end)
        last = -(-int(end) // self.block_size)
        for page in range(int(start) // self.block_size, last):
            if self.block_tables[slot, page] < 0:
                self.block_tables[slot, page] = self._alloc_block(slot)

    # ---- device-side contents ----
    def insert(self, slot: int, request_cache: dict, prompt_len: int) -> None:
        """Allocate the prompt's blocks and scatter a batch=1 dense prefill
        cache into them (the prefill cache must be sized to exactly
        ``blocks_for(prompt_len) * block_size``).

        Not on the engine's serving path since the chunked-prefill rewrite
        (prefill now writes blocks in place via ``paged_write_chunk``); kept
        as the generic externally-prefilled-cache injection API and covered
        by the pool tests."""
        nb = self.blocks_for(prompt_len)
        ids = [self._alloc_block(slot) for _ in range(nb)]
        self.block_tables[slot, :nb] = ids
        self.caches = self._insert_fn(self.caches, request_cache,
                                      jnp.asarray(ids, jnp.int32),
                                      jnp.asarray(slot, jnp.int32))

    def block_tables_device(self) -> jax.Array:
        # hand jax a private copy: on CPU, jnp.asarray(host_array) may be
        # zero-copy, and the pool mutates block_tables in place
        # (ensure_block/ensure_range/free_slot) — under the pipelined engine
        # a dispatched step may still be reading the aliased buffer when the
        # next tick's allocation rewrites it
        return jnp.asarray(self.block_tables.copy())
