"""KV/SSM cache pools for continuous batching: dense slots and paged blocks.

:class:`CachePool` (dense) owns one device cache tree whose leading (batch)
axis is the slot axis: ``n_slots`` independent sequences decode together in a
single compiled step, each slot a monolithic ``max_len`` ring. HBM scales
with ``n_slots * max_len`` regardless of live tokens.

:class:`PagedCachePool` (vLLM-style) replaces the per-slot rings with a
shared store of fixed-size blocks: attention K/V lives in block-major arrays
``(n_blocks, block_size, ...)``, each slot maps its logical pages to physical
blocks through a host-side block table, and blocks are allocated on demand as
decode crosses block boundaries — HBM scales with *live tokens*, so an MP
plan's fp8 ``kv_cache_dtype`` savings buy proportionally more concurrent
slots. Admission reserves a request's worst-case block count (prompt +
``max_new_tokens - 1`` writes), which makes mid-decode allocation infallible
while materializing blocks lazily; :meth:`can_admit` returning False is the
scheduler's backpressure signal. Physical block 0 is never allocated: it is
the trash block that absorbs writes from vacant decode rows (block-table
entries of -1 clamp to 0 inside the kernel). SSM state has no sequence axis
and stays slot-major.

Blocks are **refcounted** and may be shared read-only between slots
(automatic prefix caching, vLLM / RadixAttention precedent): prompts are
content-hashed block by block with *chained* digests
(``h_j = sha256(h_{j-1} || tokens_j)``), so one digest match implies the
whole prefix up to that block matches. A new request whose chain matches
resident blocks claims them (refcount + 1), maps them into its table, and
skips prefill for the matched tokens entirely. The last write into a shared
block triggers a **copy-on-write fork** (:meth:`ensure_range` detects a
write landing on a borrowed page): the block is duplicated on device
(:meth:`~repro.models.lm.LM.paged_copy_block`), the slot's table repoints at
the private copy, and the parent chain stays immutable. When a slot is
freed, indexed blocks whose refcount hits zero stay *cached* (content
resident, LRU-reclaimable) instead of returning to the free list —
:meth:`_alloc_block` reclaims the oldest cached block (de-indexing it) only
when the free list runs dry. ``n_free_blocks`` therefore counts free +
cached: both are allocatable capacity.

All allocation is host-side free lists; device traffic goes through
:meth:`insert` / the COW fork (one jitted scatter/copy each, traced over
slot/block ids).
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["CachePool", "PagedCachePool", "dense_slot_bytes",
           "paged_block_bytes", "paged_slot_bytes"]


def dense_slot_bytes(model, max_len: int) -> int:
    """HBM bytes one dense cache slot (KV rings + SSM state) pins at
    ``max_len`` — the dense baseline cost of a slot whether or not it holds
    live tokens."""
    from repro.nn.spec import param_bytes
    return param_bytes(model.cache_specs(1, max_len))


def paged_block_bytes(model, block_size: int) -> int:
    """HBM bytes one KV block adds across all layers (the marginal cost of
    ``block_size`` live tokens under paging)."""
    from repro.nn.spec import param_bytes
    return (param_bytes(model.paged_cache_specs(1, 2, block_size))
            - param_bytes(model.paged_cache_specs(1, 1, block_size)))


def paged_slot_bytes(model, block_size: int) -> int:
    """HBM bytes one *slot* pins under paging regardless of live tokens:
    the slot-major leaves (SSM state on mamba/hybrid archs; zero for pure
    attention). Counted so paged-vs-dense comparisons stay symmetric."""
    from repro.nn.spec import param_bytes
    return (param_bytes(model.paged_cache_specs(2, 1, block_size))
            - param_bytes(model.paged_cache_specs(1, 1, block_size)))


@jax.jit
def _scatter_slot(pool: dict, one: dict, slot: jax.Array) -> dict:
    def upd(pl, ol):
        start = (slot,) + (0,) * (pl.ndim - 1)
        return jax.lax.dynamic_update_slice(pl, ol.astype(pl.dtype), start)
    return jax.tree.map(upd, pool, one)


# one jitted copy-on-write fork per (model, layout): pools are rebuilt per
# serve() drain, so the jit cache must outlive the pool instance or every
# drain recompiles. Values keep strong refs so id() keys stay valid.
_COW_JIT_CACHE: dict = {}


class CachePool:
    """``n_slots`` x ``max_len`` dense KV/SSM cache slots for one model.

    A freshly prefilled single-request cache (batch=1) is scattered into a
    slot with one jitted ``dynamic_update_slice`` per leaf; because the
    insert overwrites the *entire* slot row — including the ring-buffer
    ``pos`` entries that gate the attention mask — stale K/V from the slot's
    previous occupant can never leak into a new request.
    """

    def __init__(self, model, n_slots: int, max_len: int, mesh_layout=None,
                 chunk_extra: int = 0):
        assert n_slots >= 1 and max_len >= 1, (n_slots, max_len)
        self.n_slots = n_slots
        self.max_len = max_len
        # chunk_extra widens windowed rings by the prefill chunk length so
        # dense chunked prefill never truncates a chunk that straddles the
        # window boundary (see kv_cache_spec); 0 keeps the legacy shapes
        kw = {"chunk_extra": chunk_extra} if chunk_extra else {}
        self.caches = model.init_cache(n_slots, max_len, **kw)
        if mesh_layout is not None:
            from repro.serve.parallel import shard_cache_tree
            self.caches = shard_cache_tree(
                model, self.caches, model.cache_specs(n_slots, max_len, **kw),
                mesh_layout.mesh)
        self._free = list(range(n_slots - 1, -1, -1))  # pop() yields slot 0 first

    # ---- host-side slot accounting ----
    @property
    def n_free(self) -> int:
        return len(self._free)

    # uniform pool interface (shared with PagedCachePool)
    @property
    def n_free_slots(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """Claim a free slot index; raises RuntimeError when the pool is full."""
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop()

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self._free.append(slot)

    def free_slot(self, slot: int) -> None:
        self.free(slot)

    # ---- device-side slot contents ----
    def insert(self, slot: int, request_cache: dict) -> None:
        """Scatter a batch=1 cache tree into ``slot`` (overwrites the row).
        Not on the engine's serving path since the chunked-prefill rewrite
        (dense prefill now writes the pool row in place); kept as the
        generic cache-injection API and covered by the pool tests."""
        self.caches = _scatter_slot(self.caches, request_cache,
                                    jnp.asarray(slot, jnp.int32))

    # uniform pool interface: dense slots carry no cross-drain state
    def reset_counters(self) -> None:
        pass

    def invalidate_prefix_index(self) -> None:
        pass


class PagedCachePool:
    """Paged KV storage: ``n_blocks`` blocks of ``block_size`` tokens shared
    by ``n_slots`` decode rows through per-slot block tables.

    Invariants the attention kernel relies on (see ``nn/layers.py``):

    * a block has exactly one *writer* at a time (block 0 by nobody — it is
      the trash sink for vacant rows), but may have many concurrent
      *readers*: a refcounted prefix block appears in several slots' tables
      and every logical position <= each slot's write position holds valid
      token data for that slot, because a shared block's content is
      bit-identical to what each sharer's own prefill would have written
      (per-token quant scales make K/V a pure function of the tokens at and
      before each position);
    * a slot never writes a shared block: the only write that could land in
      one (the tail chunk of a fully-matched prompt) forks it first
      (copy-on-write), and decode writes always target pages past the
      matched prefix;
    * a slot's pages are allocated in logical order and written
      contiguously, so the causal mask alone separates live keys from stale
      block contents — freed blocks need no device-side scrubbing before
      reuse.

    Admission accounting: :meth:`alloc_slot` *reserves* the request's
    worst-case block count without materializing it; :meth:`ensure_block` /
    :meth:`ensure_range` then draw on the reservation as prefill/decode
    cross block boundaries. Matched prefix blocks are claimed instead of
    reserved (refcount + 1, no new capacity), shrinking the reservation by
    one block per hit. ``can_admit`` is False while no shard's
    free-plus-cached-minus-reserved budget covers a new request — the
    backpressure signal the scheduler turns into queueing or preemption.

    Prefix index: per shard, ``digest -> block`` for fully-written prompt
    blocks (chained sha256 over the block's tokens — see
    :meth:`prefix_digests`). Blocks whose refcount drops to zero while
    indexed move to a per-shard cached-LRU (content resident, allocatable);
    :meth:`_alloc_block` reclaims the least recently released cached block
    — de-indexing it, which truncates any chain through it — only after the
    free list empties, so resident prefixes survive as long as capacity
    allows. Eviction therefore never reclaims a block with a nonzero
    refcount.

    Mesh sharding: with a ``mesh_layout`` whose ``shard_pages`` is set, the
    physical pool splits into ``data`` equal shards — shard ``d`` owns the
    contiguous page range ``[d*bps, (d+1)*bps)`` plus its own trash block at
    ``d*bps`` — and every slot draws blocks exclusively from its own shard
    (slot ``s`` lives on shard ``s // slots_per_shard``, matching the
    contiguous slot-axis sharding over ``data``). The prefix index is
    per-shard for the same reason: a slot can only map blocks that live on
    its own data shard, so a prefix resident on another shard is a miss.
    Admission planning (:meth:`_plan_admission`) is shard-aware twice over:
    it gates on *per-shard* free-list pressure (one hot shard cannot strand
    the others' capacity) and places a request on the shard where its
    prefix chain is longest. Block tables keep *global* ids; the shard_map
    kernel path translates them to shard-local ids. With one shard the
    allocator is bit-for-bit the single-device one (same free lists, same
    pop order).
    """

    def __init__(self, model, n_slots: int, max_len: int,
                 block_size: int = 16, n_blocks=None, mesh_layout=None,
                 data_shards: int = 1):
        assert n_slots >= 1 and max_len >= 1 and block_size >= 1
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.block_size = block_size
        self.layout = mesh_layout
        self.max_blocks = -(-max_len // block_size)     # table width per slot
        # data_shards is the host-accounting hook for testing the sharded
        # allocator without devices; with a real mesh the layout wins
        data = mesh_layout.data if mesh_layout is not None else data_shards
        n_blocks, shard_pages, bps = self.plan_blocks(
            n_slots, max_len, block_size, n_blocks=n_blocks, data_shards=data)
        if mesh_layout is not None:
            assert (n_blocks, shard_pages) == (mesh_layout.n_blocks,
                                               mesh_layout.shard_pages), \
                "pool geometry disagrees with the serving mesh layout"
        self.n_blocks = n_blocks
        self.n_shards = data if shard_pages else 1
        self.blocks_per_shard = bps
        self.slots_per_shard = n_slots // self.n_shards
        self.caches = model.init_paged_cache(n_slots, n_blocks, block_size)
        if mesh_layout is not None:
            from repro.serve.parallel import shard_cache_tree
            self.caches = shard_cache_tree(
                model, self.caches,
                model.paged_cache_specs(n_slots, n_blocks, block_size),
                mesh_layout.mesh)
        self._insert_fn = jax.jit(model.paged_insert)
        # per-shard free lists; shard d's trash block d*bps is never listed
        # (single shard: blocks [1, n_blocks), trash 0 — the legacy layout)
        self._free_blocks_by_shard = [
            list(range((d + 1) * bps - 1, d * bps, -1))
            for d in range(self.n_shards)]
        self._free_slots_by_shard = [
            list(range((d + 1) * self.slots_per_shard - 1,
                       d * self.slots_per_shard - 1, -1))
            for d in range(self.n_shards)]
        self._reserved_by_shard = [0] * self.n_shards
        self._slot_reserve: dict = {}       # slot -> outstanding reservation
        self._slot_blocks: dict = {}        # slot -> [referenced block ids]
        self.block_tables = np.full((n_slots, self.max_blocks), -1, np.int32)
        # ---- prefix sharing state ----
        self._ref: dict = {}                # block -> refcount (materialized)
        self._index_by_shard = [dict() for _ in range(self.n_shards)]
        self._block_digest: dict = {}       # block -> (shard, digest)
        self._cached_by_shard = [OrderedDict()      # refcount-0 indexed
                                 for _ in range(self.n_shards)]  # blocks, LRU
        self._slot_digests: dict = {}       # slot -> prompt block digests
        self._slot_borrowed: dict = {}      # slot -> {shared page indices}
        self._slot_matched: dict = {}       # slot -> matched prefix tokens
        self._slot_registered: dict = {}    # slot -> pages indexed so far
        self.prefix_hit_requests = 0
        self.prefix_hit_blocks = 0
        self.prefix_hit_tokens = 0
        self.cow_forks = 0
        self.reclaimed_cached_blocks = 0
        # ---- fault containment: poisoned-block quarantine ----
        # quarantined blocks are permanently out of circulation: never on a
        # free list, never cached, never indexed — capacity shrinks by one
        # block each, which later allocations feel as organic pressure
        self._quarantined_by_shard = [set() for _ in range(self.n_shards)]
        # blocks awaiting quarantine: still referenced by a borrower the
        # fork-off couldn't relocate (pool dry); _release routes them into
        # quarantine the moment the last reference drops
        self._quarantine_pending: set = set()
        self.quarantined_blocks = 0          # per-drain tally

    # ---- cross-drain lifecycle ----------------------------------------
    def reset_counters(self) -> None:
        """Zero the per-drain telemetry tallies. The engine persists one
        pool across ``serve()`` drains (so the prefix index survives between
        calls); each drain's counters start fresh here. Quarantined blocks
        stay quarantined — only the drain tally resets."""
        self.prefix_hit_requests = 0
        self.prefix_hit_blocks = 0
        self.prefix_hit_tokens = 0
        self.cow_forks = 0
        self.reclaimed_cached_blocks = 0
        self.quarantined_blocks = 0

    def invalidate_prefix_index(self) -> None:
        """Forget every indexed prefix block. Cached (refcount-0) blocks
        return to the free lists; blocks still referenced by live slots stay
        mapped but are de-indexed, so they rejoin the free list on release
        instead of the cached LRU. Live slots' digest chains are dropped too
        — no block written before this call can ever satisfy a future hit.

        Called on an adaptive MP plan swap: quantized K/V bytes are a
        function of the *plan* (activation scales and cache formats differ),
        so content indexed under the old plan must not be claimed by
        requests admitted under the new one."""
        for blk in list(self._block_digest):
            d, _ = self._block_digest[blk]
            self._deindex(blk)
            if blk in self._cached_by_shard[d]:
                del self._cached_by_shard[d][blk]
                self._free_blocks_by_shard[d].append(blk)
        for idx in self._index_by_shard:
            idx.clear()
        for slot in self._slot_digests:
            self._slot_digests[slot] = []

    # ---- geometry -----------------------------------------------------
    @staticmethod
    def plan_blocks(n_slots: int, max_len: int, block_size: int,
                    n_blocks=None, data_shards: int = 1) -> tuple:
        """Resolve the pool geometry: ``(n_blocks, shard_pages,
        blocks_per_shard)``. The single source of truth shared by the pool
        allocator and :func:`repro.serve.parallel.make_serving_layout`.

        Pages shard over ``data`` only when both the slot axis and the block
        count split evenly; otherwise the pool stays replicated (matching
        the ``kv_blocks`` rule's divisibility fallback) and allocation is
        global with the single trash block 0."""
        max_blocks = -(-max_len // block_size)
        slots_ok = data_shards > 1 and n_slots % data_shards == 0
        if n_blocks is None:
            # worst case: every slot decodes to max_len (same HBM as dense,
            # modulo block rounding); size it tighter to realize the win —
            # see size_n_blocks. Sharded pools give every shard its own
            # trash block so per-shard capacity stays uniform.
            n_blocks = (data_shards * (1 + (n_slots // data_shards) * max_blocks)
                        if slots_ok else 1 + n_slots * max_blocks)
        shard = (slots_ok and n_blocks % data_shards == 0
                 and n_blocks >= 2 * data_shards)
        assert n_blocks >= 2, "need at least the trash block plus one"
        return n_blocks, shard, n_blocks // (data_shards if shard else 1)

    @staticmethod
    def size_n_blocks(profile, n_slots: int, block_size: int, *,
                      percentile: float = 95.0, headroom: float = 1.25,
                      data_shards: int = 1) -> int:
        """Size ``n_blocks`` from a measured request profile instead of the
        worst case: simulate the FCFS live-block trajectory of ``profile``
        (an iterable of ``(prompt_len, max_new_tokens)`` pairs) over
        ``n_slots`` decode rows at one decode step per tick, take the given
        ``percentile`` of the per-tick live-block totals, multiply by
        ``headroom`` (the SLA knob: how much of the tail demand the pool
        must absorb without backpressure), and add the trash block(s).

        The result is clamped to ``[largest single request + trash,
        worst case]`` and rounded up to a multiple of ``data_shards`` so a
        sharded pool splits evenly. Sub-worst-case sizing trades HBM for
        occasional admission backpressure — exactly the dial the paper's
        gained-time-vs-constraint framing prices."""
        profile = [(int(p), int(m)) for p, m in profile]
        if not profile:
            raise ValueError("size_n_blocks needs a non-empty profile")
        bf = lambda n: max(-(-n // block_size), 1)
        max_blocks_req = max(bf(p + max(m - 1, 0)) for p, m in profile)
        worst = n_slots * max(max_blocks_req, 1)
        # FCFS over n_slots rows: request occupies its slot for max(m, 1)
        # ticks; at decode tick t it holds the blocks covering p + t tokens
        free_at = [0] * n_slots
        demand: dict = {}
        for p, m in profile:
            s = min(range(n_slots), key=free_at.__getitem__)
            start, dur = free_at[s], max(m, 1)
            for t in range(dur):
                demand[start + t] = demand.get(start + t, 0) + bf(p + t)
            free_at[s] = start + dur
        live = sorted(demand.values())
        idx = min(int(len(live) * percentile / 100.0), len(live) - 1)
        need = int(np.ceil(live[idx] * headroom))
        need = max(min(need, worst), max_blocks_req)
        n = need + max(data_shards, 1)                     # trash block(s)
        if data_shards > 1:                                # even shard split
            n = -(-n // data_shards) * data_shards
        return n

    # ---- budget / accounting ----
    @property
    def n_free_slots(self) -> int:
        return sum(len(s) for s in self._free_slots_by_shard)

    @property
    def n_free_blocks(self) -> int:
        """Allocatable blocks: truly free plus cached (refcount-0 indexed
        blocks are resident prefix content, reclaimed on demand)."""
        return (sum(len(b) for b in self._free_blocks_by_shard)
                + self.n_cached_blocks)

    @property
    def n_cached_blocks(self) -> int:
        return sum(len(c) for c in self._cached_by_shard)

    @property
    def n_quarantined_blocks(self) -> int:
        return sum(len(q) for q in self._quarantined_by_shard)

    @property
    def blocks_in_use(self) -> int:
        return ((self.n_blocks - self.n_shards - self.n_quarantined_blocks)
                - self.n_free_blocks)

    @property
    def _reserved(self) -> int:
        return sum(self._reserved_by_shard)

    @property
    def allocatable_blocks(self) -> int:
        """Largest single-request reservation the pool can ever satisfy —
        one shard's capacity minus its trash block and any quarantined
        blocks (quarantine permanently shrinks capacity)."""
        return (self.blocks_per_shard - 1
                - min(len(q) for q in self._quarantined_by_shard))

    def _shard_of(self, slot: int) -> int:
        return slot // self.slots_per_shard if self.n_shards > 1 else 0

    def blocks_for(self, n_tokens: int) -> int:
        return max(-(-n_tokens // self.block_size), 1)

    def blocks_for_request(self, prompt_len: int, max_new_tokens: int) -> int:
        """Worst-case blocks a request can touch: the prompt plus one KV
        write per decode step (the last generated token is never written)."""
        return self.blocks_for(prompt_len + max(max_new_tokens - 1, 0))

    # ---- prefix hashing / matching ------------------------------------
    def prefix_digests(self, tokens) -> list:
        """Chained content digests of every *full* block of ``tokens``:
        ``h_j = sha256(h_{j-1} || tokens[j*bs:(j+1)*bs])``. Because each
        digest folds in the whole chain before it, one index hit at block j
        implies blocks 0..j all match — matching is a single walk down the
        chain, no per-block prefix comparison."""
        toks = np.ascontiguousarray(np.asarray(tokens, np.int32))
        bs = self.block_size
        out, h = [], b""
        for j in range(toks.shape[0] // bs):
            h = hashlib.sha256(h + toks[j * bs:(j + 1) * bs].tobytes()).digest()
            out.append(h)
        return out

    def _match_blocks(self, d: int, digests) -> list:
        """Longest resident chain on shard ``d``: blocks for digests[0..m)."""
        blks = []
        idx = self._index_by_shard[d]
        for h in digests:
            b = idx.get(h)
            if b is None:
                break
            blks.append(b)
        return blks

    def _plan_admission(self, prompt_len: int, max_new_tokens: int,
                        digests=None):
        """Shard-aware admission plan: for every shard with a free slot,
        walk the request's digest chain against that shard's index and
        check the *net* block need (worst case minus matched, plus one for
        the copy-on-write fork a fully-matched prompt's tail chunk needs)
        against the shard's own free + cached - reserved budget. Returns
        ``(shard, matched_blocks, matched_tokens, need)`` for the shard
        with the longest match (free capacity breaks ties), or None when no
        shard can admit — per-shard gating, so one hot shard can't strand
        capacity on the others."""
        total = self.blocks_for_request(prompt_len, max_new_tokens)
        best = None
        for d in range(self.n_shards):
            if not self._free_slots_by_shard[d]:
                continue
            blks = self._match_blocks(d, digests) if digests else []
            m = len(blks)
            matched = m * self.block_size
            cow = 0
            if m and matched >= prompt_len:
                # full-prompt hit: the tail chunk still runs (it produces
                # the first token) and must fork the last shared block
                matched = prompt_len - 1
                cow = 1
            need = total - m + cow
            cached = self._cached_by_shard[d]
            claim_from_cached = sum(1 for b in blks if b in cached)
            avail = (len(self._free_blocks_by_shard[d]) + len(cached)
                     - claim_from_cached - self._reserved_by_shard[d])
            if need > avail:
                continue
            key = (m, avail, -d)
            if best is None or key > best[0]:
                best = (key, (d, blks, matched, need))
        return None if best is None else best[1]

    def can_admit(self, prompt_len: int, max_new_tokens: int,
                  digests=None) -> bool:
        return self._plan_admission(prompt_len, max_new_tokens,
                                    digests) is not None

    def matched_tokens(self, slot: int) -> int:
        """Prefix tokens ``slot`` inherited at admission — its prefill
        starts there instead of 0."""
        return self._slot_matched.get(slot, 0)

    # ---- slot lifecycle ----
    def alloc_slot(self, prompt_len: int, max_new_tokens: int,
                   digests=None) -> int:
        """Claim a slot, map any matched prefix blocks into its table
        (refcount + 1 each), and reserve the rest of the request's
        worst-case block budget."""
        total = self.blocks_for_request(prompt_len, max_new_tokens)
        if total > self.allocatable_blocks:
            raise ValueError(
                f"request needs {total} blocks but the pool only has "
                f"{self.allocatable_blocks} allocatable blocks"
                + (" per shard" if self.n_shards > 1 else ""))
        plan = self._plan_admission(prompt_len, max_new_tokens, digests)
        if plan is None:
            raise RuntimeError("paged cache pool exhausted")
        d, blks, matched, need = plan
        slot = self._free_slots_by_shard[d].pop()
        self._reserved_by_shard[d] += need
        self._slot_reserve[slot] = need
        self._slot_blocks[slot] = []
        self._slot_digests[slot] = list(digests) if digests else []
        self._slot_borrowed[slot] = set()
        self._slot_matched[slot] = matched
        self._slot_registered[slot] = 0
        for j, b in enumerate(blks):
            self._claim(d, b)
            self.block_tables[slot, j] = b
            self._slot_blocks[slot].append(b)
            self._slot_borrowed[slot].add(j)
        if blks:
            self.prefix_hit_requests += 1
            self.prefix_hit_blocks += len(blks)
            self.prefix_hit_tokens += matched
        return slot

    def free_slot(self, slot: int) -> None:
        """Return the slot, drop its block references (refcount-0 indexed
        blocks stay cached for future prefix hits; unindexed blocks rejoin
        the free list), and release any unused reservation."""
        d = self._shard_of(slot)
        assert slot not in self._free_slots_by_shard[d], slot
        for b in reversed(self._slot_blocks.pop(slot, [])):
            self._release(d, b)
        self._reserved_by_shard[d] -= self._slot_reserve.pop(slot, 0)
        for per_slot in (self._slot_digests, self._slot_borrowed,
                         self._slot_matched, self._slot_registered):
            per_slot.pop(slot, None)
        self.block_tables[slot] = -1
        self._free_slots_by_shard[d].append(slot)

    # ---- refcounted block lifecycle -----------------------------------
    def _claim(self, d: int, blk: int) -> None:
        """Take a reference on a resident block (a prefix hit)."""
        self._ref[blk] = self._ref.get(blk, 0) + 1
        self._cached_by_shard[d].pop(blk, None)     # in use again

    def _release(self, d: int, blk: int) -> None:
        self._ref[blk] -= 1
        assert self._ref[blk] >= 0, (blk, self._ref[blk])
        if self._ref[blk] == 0:
            del self._ref[blk]
            if blk in self._quarantine_pending:
                # a poisoned block whose last borrower just let go: it goes
                # straight to quarantine, never back into circulation
                self._quarantine_pending.discard(blk)
                self._quarantine(d, blk)
            elif blk in self._block_digest:
                # indexed content stays resident (LRU reclaim on pressure)
                self._cached_by_shard[d][blk] = None
            else:
                self._free_blocks_by_shard[d].append(blk)

    def _deindex(self, blk: int) -> None:
        d, h = self._block_digest.pop(blk)
        if self._index_by_shard[d].get(h) == blk:
            del self._index_by_shard[d][h]

    def _alloc_block(self, slot: int) -> int:
        d = self._shard_of(slot)
        if self._free_blocks_by_shard[d]:
            blk = self._free_blocks_by_shard[d].pop()
        elif self._cached_by_shard[d]:
            # reclaim the least recently released cached block; de-indexing
            # it truncates any digest chain through it (later links become
            # unreachable, which is safe: a chain hit requires every link)
            blk, _ = self._cached_by_shard[d].popitem(last=False)
            self._deindex(blk)
            self.reclaimed_cached_blocks += 1
        else:
            raise RuntimeError("paged cache pool out of blocks")
        if self._slot_reserve.get(slot, 0) > 0:
            self._slot_reserve[slot] -= 1
            self._reserved_by_shard[d] -= 1
        self._ref[blk] = 1
        self._slot_blocks[slot].append(blk)
        return blk

    def register_prefix(self, slot: int, upto_tokens: int) -> None:
        """Index ``slot``'s fully-written prompt blocks (logical positions
        ``[0, upto_tokens)``) under their chain digests so later requests
        can match them. Idempotent and incremental: call after each prefill
        chunk with the cumulative prefilled length. First writer wins — a
        digest already indexed (e.g. the block this slot itself borrowed)
        is skipped, keeping exactly one canonical block per chain node.

        Safe to call right after the chunk *dispatches* (before the device
        writes land): any future reader's chunks are dispatched later on
        the same device stream, so they order after this slot's writes."""
        digests = self._slot_digests.get(slot)
        if not digests:
            return
        d = self._shard_of(slot)
        idx = self._index_by_shard[d]
        done = self._slot_registered.get(slot, 0)
        end = min(int(upto_tokens) // self.block_size, len(digests))
        for j in range(done, end):
            h = digests[j]
            blk = int(self.block_tables[slot, j])
            assert blk >= 0, (slot, j)
            if h not in idx:
                idx[h] = blk
                self._block_digest[blk] = (d, h)
        self._slot_registered[slot] = max(done, end)

    # ---- fault containment: quarantine + reconcile --------------------
    def _quarantine(self, d: int, blk: int) -> None:
        """Retire ``blk`` from circulation permanently. Caller guarantees
        the refcount is zero (no table maps it)."""
        if blk in self._block_digest:
            self._deindex(blk)
        self._quarantined_by_shard[d].add(blk)
        self.quarantined_blocks += 1

    def _alloc_block_unreserved(self, d: int):
        """Pop a block from shard ``d`` without charging any slot's
        reservation — the quarantine fork-off path: the copy a borrower
        needs was never part of its admission-time budget. Returns None
        (instead of raising) when the shard is dry: the caller degrades
        gracefully. May leave ``reserved > free + cached``; a later
        ``_alloc_block`` then raises, which the engine contains as an
        allocation fault — quarantine pressure surfaces as backpressure,
        never as a crash."""
        if self._free_blocks_by_shard[d]:
            blk = self._free_blocks_by_shard[d].pop()
        elif self._cached_by_shard[d]:
            blk, _ = self._cached_by_shard[d].popitem(last=False)
            self._deindex(blk)
            self.reclaimed_cached_blocks += 1
        else:
            return None
        self._ref[blk] = 1
        return blk

    def _fork_off(self, slot: int, page: int) -> bool:
        """Copy ``slot``'s (borrowed) ``page`` onto a private block so the
        quarantined source loses this reader. The copy may itself carry
        poisoned bytes — if it does, the borrower's own tripwire fires and
        containment recurses; what quarantine guarantees is that the *block*
        can never be re-allocated or prefix-matched again. False when the
        pool is dry (the borrower keeps the pending-quarantine page)."""
        d = self._shard_of(slot)
        src = int(self.block_tables[slot, page])
        dst = self._alloc_block_unreserved(d)
        if dst is None:
            return False
        self._slot_blocks[slot].append(dst)
        self._copy_block_device(src, dst)
        self.block_tables[slot, page] = dst
        self._slot_blocks[slot].remove(src)
        self._release(d, src)
        borrowed = self._slot_borrowed.get(slot)
        if borrowed is not None:
            borrowed.discard(page)
        return True

    def quarantine_slot(self, slot: int) -> int:
        """Poisoned-page containment for a faulted slot: every block its
        table maps is (1) de-indexed — no future prefix hit can walk through
        it; (2) stripped of other live borrowers via device-side fork-off
        copies; (3) dropped from this slot's table and retired to the
        quarantine set, from which no allocation path (free list, cached
        LRU) can ever produce it again. Conservative by design: detection
        is a non-finite *logit* row, which does not localize the poisoned
        page, so the whole mapping is suspect. Returns the number of blocks
        newly quarantined (borrowed blocks whose fork-off failed quarantine
        later, on their last release). Call before ``free_slot``."""
        d = self._shard_of(slot)
        before = self.quarantined_blocks
        borrowed = self._slot_borrowed.get(slot, set())
        for page in range(self.max_blocks):
            blk = int(self.block_tables[slot, page])
            if blk < 0:
                continue
            if blk in self._block_digest:
                self._deindex(blk)
            self._quarantine_pending.add(blk)
            for t in range(self.n_slots):
                if t == slot:
                    continue
                for p in np.nonzero(self.block_tables[t] == blk)[0]:
                    self._fork_off(t, int(p))
            self.block_tables[slot, page] = -1
            self._slot_blocks[slot].remove(blk)
            borrowed.discard(page)
            self._release(d, blk)
        return self.quarantined_blocks - before

    def poison_block(self, blk: int) -> None:
        """Overwrite physical block ``blk`` with NaN in every block-major
        floating-point cache leaf — the fault injector's NaN-page primitive
        (device-side, one jitted scatter). Test harness only."""
        # n_blocks is baked into the closure's leaf filter, so pools with
        # different geometries must not share a compiled poisoner
        key = (id(self.model), id(self.layout), "poison", self.n_blocks)
        entry = _COW_JIT_CACHE.get(key)
        if entry is None:
            nb = self.n_blocks

            def _poison(caches, b):
                def upd(x):
                    if (x.ndim >= 2 and x.shape[0] == nb
                            and jnp.issubdtype(x.dtype, jnp.floating)):
                        return x.at[b].set(jnp.nan)
                    return x
                return jax.tree.map(upd, caches)

            kw = {}
            if self.layout is not None:
                kw["out_shardings"] = jax.tree.map(lambda x: x.sharding,
                                                   self.caches)
            entry = (self.model, self.layout, jax.jit(_poison, **kw))
            _COW_JIT_CACHE[key] = entry
        self.caches = entry[2](self.caches, jnp.asarray(blk, jnp.int32))

    def check_consistency(self) -> dict:
        """Cross-check the allocator's books against the block tables (the
        single source of truth for what is mapped): table multiset ==
        refcounts, and free/cached/quarantined sets disjoint from mapped
        blocks. Returns a report dict with ``ok`` plus the mismatches."""
        from collections import Counter
        mat = Counter(int(b) for row in self.block_tables
                      for b in row if b >= 0)
        free = set()
        for lst in self._free_blocks_by_shard:
            free.update(lst)
        cached = set()
        for c in self._cached_by_shard:
            cached.update(c)
        quarantined = set()
        for q in self._quarantined_by_shard:
            quarantined.update(q)
        mapped = set(mat)
        report = {
            "tables_vs_ref": mat == Counter(self._ref),
            "free_mapped": sorted(free & mapped),
            "cached_mapped": sorted(cached & mapped),
            "quarantined_mapped": sorted(quarantined & mapped),
            "quarantined_free": sorted(quarantined & (free | cached)),
        }
        report["ok"] = (report["tables_vs_ref"]
                        and not report["free_mapped"]
                        and not report["cached_mapped"]
                        and not report["quarantined_mapped"]
                        and not report["quarantined_free"])
        return report

    def reconcile(self) -> dict:
        """Repair the allocator's books after an error bail-out: recompute
        every refcount from the block tables and route orphaned blocks
        (referenced by no table) back to the cached LRU / free list — or to
        quarantine if poisoned. Run by the engine on the consumer-error
        shutdown path, after every slot has been released, so a drain that
        re-raises a callback error still leaves the persistent pool in a
        state the next drain can safely reuse. Returns what changed."""
        from collections import Counter
        mat = Counter(int(b) for row in self.block_tables
                      for b in row if b >= 0)
        fixed = 0
        for blk, want in mat.items():
            if self._ref.get(blk) != want:
                self._ref[blk] = want
                fixed += 1
        orphans = 0
        for blk in [b for b in self._ref if b not in mat]:
            del self._ref[blk]
            d = self._shard_of_block(blk)
            self._cached_by_shard[d].pop(blk, None)
            if blk in self._free_blocks_by_shard[d]:
                continue
            if blk in self._quarantine_pending:
                self._quarantine_pending.discard(blk)
                self._quarantine(d, blk)
            elif blk in self._block_digest:
                self._cached_by_shard[d][blk] = None
            else:
                self._free_blocks_by_shard[d].append(blk)
            orphans += 1
        return {"ref_fixed": fixed, "orphans_rerouted": orphans,
                "consistent": self.check_consistency()["ok"]}

    def _shard_of_block(self, blk: int) -> int:
        return blk // self.blocks_per_shard if self.n_shards > 1 else 0

    def _cow_fork(self, slot: int, page: int) -> None:
        """Copy-on-write: ``slot`` is about to write into shared ``page`` —
        duplicate the block on device, repoint the table at the private
        copy, and drop the shared reference. The parent block (and the
        chain through it) is never mutated."""
        d = self._shard_of(slot)
        src = int(self.block_tables[slot, page])
        dst = self._alloc_block(slot)       # before release: the fork must
        self._copy_block_device(src, dst)   # never reclaim its own source
        self.block_tables[slot, page] = dst
        self._slot_blocks[slot].remove(src)
        self._release(d, src)
        self._slot_borrowed[slot].discard(page)
        self.cow_forks += 1

    def _copy_block_device(self, src: int, dst: int) -> None:
        key = (id(self.model), id(self.layout))
        entry = _COW_JIT_CACHE.get(key)
        if entry is None:
            kw = {}
            if self.layout is not None:
                kw["out_shardings"] = jax.tree.map(lambda x: x.sharding,
                                                   self.caches)
            entry = (self.model, self.layout,
                     jax.jit(self.model.paged_copy_block, **kw))
            _COW_JIT_CACHE[key] = entry
        self.caches = entry[2](self.caches, jnp.asarray(src, jnp.int32),
                               jnp.asarray(dst, jnp.int32))

    def ensure_block(self, slot: int, pos: int) -> None:
        """Alloc-on-demand: materialize the page for write position ``pos``
        when decode crosses a block boundary. Covered by the admission-time
        reservation, so it cannot fail for an admitted request."""
        page, off = divmod(int(pos), self.block_size)
        if off == 0 and self.block_tables[slot, page] < 0:
            self.block_tables[slot, page] = self._alloc_block(slot)
        else:
            # decode never writes a shared page: matched prefixes end
            # before the first decode position, and a fully-matched
            # prompt's last block was forked by the tail prefill chunk
            borrowed = self._slot_borrowed.get(slot)
            assert not borrowed or page not in borrowed, (slot, page)

    def ensure_range(self, slot: int, start: int, end: int) -> None:
        """Materialize every page covering logical positions [start, end) —
        chunked prefill's incremental reservation: blocks appear chunk by
        chunk (each drawing on the admission-time reservation) instead of
        the whole prompt's worth at once, so blocks a later chunk will fill
        stay in the free pool until that chunk actually runs. A page that
        is present but *borrowed* (shared prefix block) is copy-on-write
        forked before the chunk writes into it — this only happens for the
        tail chunk of a fully-matched prompt."""
        assert 0 <= start < end, (start, end)
        borrowed = self._slot_borrowed.get(slot)
        last = -(-int(end) // self.block_size)
        for page in range(int(start) // self.block_size, last):
            if self.block_tables[slot, page] < 0:
                self.block_tables[slot, page] = self._alloc_block(slot)
            elif borrowed and page in borrowed:
                self._cow_fork(slot, page)

    # ---- device-side contents ----
    def insert(self, slot: int, request_cache: dict, prompt_len: int) -> None:
        """Allocate the prompt's blocks and scatter a batch=1 dense prefill
        cache into them (the prefill cache must be sized to exactly
        ``blocks_for(prompt_len) * block_size``).

        Not on the engine's serving path since the chunked-prefill rewrite
        (prefill now writes blocks in place via ``paged_write_chunk``); kept
        as the generic externally-prefilled-cache injection API and covered
        by the pool tests."""
        nb = self.blocks_for(prompt_len)
        ids = [self._alloc_block(slot) for _ in range(nb)]
        self.block_tables[slot, :nb] = ids
        self.caches = self._insert_fn(self.caches, request_cache,
                                      jnp.asarray(ids, jnp.int32),
                                      jnp.asarray(slot, jnp.int32))

    def block_tables_device(self) -> jax.Array:
        # hand jax a private copy: on CPU, jnp.asarray(host_array) may be
        # zero-copy, and the pool mutates block_tables in place
        # (ensure_block/ensure_range/free_slot) — under the pipelined engine
        # a dispatched step may still be reading the aliased buffer when the
        # next tick's allocation rewrites it
        return jnp.asarray(self.block_tables.copy())
