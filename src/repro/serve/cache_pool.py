"""Slot-based KV/SSM cache pool for continuous batching.

The pool owns one device cache tree whose leading (batch) axis is the slot
axis: ``n_slots`` independent sequences decode together in a single compiled
step. A freshly prefilled single-request cache (batch=1) is scattered into a
slot with one jitted ``dynamic_update_slice`` per leaf; because the insert
overwrites the *entire* slot row — including the ring-buffer ``pos`` entries
that gate the attention mask — stale K/V from the slot's previous occupant
can never leak into a new request.

Slot allocation is a plain free list on the host; all device traffic goes
through :meth:`insert`. The ``slot`` index is a traced argument, so inserts
at different slots reuse one compiled scatter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["CachePool"]


@jax.jit
def _scatter_slot(pool: dict, one: dict, slot: jax.Array) -> dict:
    def upd(pl, ol):
        start = (slot,) + (0,) * (pl.ndim - 1)
        return jax.lax.dynamic_update_slice(pl, ol.astype(pl.dtype), start)
    return jax.tree.map(upd, pool, one)


class CachePool:
    """``n_slots`` x ``max_len`` KV/SSM cache slots for one model."""

    def __init__(self, model, n_slots: int, max_len: int):
        assert n_slots >= 1 and max_len >= 1, (n_slots, max_len)
        self.n_slots = n_slots
        self.max_len = max_len
        self.caches = model.init_cache(n_slots, max_len)
        self._free = list(range(n_slots - 1, -1, -1))  # pop() yields slot 0 first

    # ---- host-side slot accounting ----
    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self) -> int:
        """Claim a free slot index; raises RuntimeError when the pool is full."""
        if not self._free:
            raise RuntimeError("cache pool exhausted")
        return self._free.pop()

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.n_slots and slot not in self._free, slot
        self._free.append(slot)

    # ---- device-side slot contents ----
    def insert(self, slot: int, request_cache: dict) -> None:
        """Scatter a batch=1 cache tree into ``slot`` (overwrites the row)."""
        self.caches = _scatter_slot(self.caches, request_cache,
                                    jnp.asarray(slot, jnp.int32))
