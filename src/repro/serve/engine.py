"""Serving engines: one-shot batch serving and continuous batching.

TTFT (the paper's measured quantity, Sec. 2.3.1) = wall time of the compiled
prefill step. Both engines accept ``mp`` as an op->format dict *or* an
``MPPlan`` straight from ``core.pipeline.auto_mixed_precision``, so an
IP-solver artifact is directly servable.

* :class:`ServeEngine` — the paper-measurement harness: one batch in, greedy
  decode to completion, report TTFT + decode throughput.
* :class:`ContinuousBatchingEngine` — production-shaped serving: a request
  queue drains through a fixed pool of cache slots; requests are admitted
  *mid-decode* as slots free up (scheduler), each prefilled request's cache
  is scattered into its slot (cache pool), and one compiled decode step
  advances every occupied slot at its own sequence depth (per-slot position
  vectors). Greedy tokens are identical to the one-shot path — batching is
  across independent cache rows, never across a sequence's own math.

Continuous serving defaults to the **paged** KV layout (``paged=True``):
attention caches are block-major (``PagedCachePool``), admission is
block-budget-aware (a request only enters when its worst-case block need is
coverable — otherwise it queues, the backpressure path), and the compiled
decode step takes the per-slot block tables. ``paged=False`` keeps the dense
per-slot rings for comparison. Paged decode attention defaults to the
**fused** Pallas kernel (``paged_attn="fused"``): block-table indirection is
resolved in-kernel and each step reads only live KV blocks (fp8 caches
dequantized in-register), instead of the ``paged_attn="gather"`` reference
path that materializes the full ``(B, max_blocks * block_size)`` K/V per
layer. Token parity with the dense/one-shot path is exact either way: the
fused kernel reproduces the reference softmax numerics (two-phase, final
max/denominator), the paged gather reproduces the dense key layout in
logical order, and the causal mask / length masking hides everything else.

Prefill is **length-bucketed** in both engines: prompts are padded to a
power-of-two bucket with masked attention/state updates, so admission
compiles O(#buckets) programs instead of O(#distinct prompt lengths). In
paged mode it is additionally **chunked** (``chunk_len``): a prompt longer
than the chunk budget is split into fixed-size chunks written straight into
the slot's paged blocks ("paged prefill" — no dense-then-scatter), each
chunk interleaved with decode steps under a TTFT-aware arbitration budget
(``chunk_budget`` chunk steps per decode step at most), so a long prompt
consumes bounded per-step latency and never head-of-line-blocks decoding
slots. Greedy tokens stay bit-identical to the one-shot engine for prompts
whose bucket stays below ``flash_min_seq``: the serving quant policy uses
per-token activation scales and prefill attends through the KV-cache
storage dtype, making the math invariant to batching, padding and chunk
splits. (At or past ``flash_min_seq`` the one-shot engine takes the
blocked flash kernel, whose summation order differs from the reference
path the chunked step always uses — see the serve README.)
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpconfig import as_assignment
from repro.launch.steps import (get_serving_step, greedy_next_token,
                                merge_first_tokens, nonfinite_rows,
                                shadow_logit_mse)
from repro.serve.cache_pool import (CachePool, PagedCachePool,
                                    dense_slot_bytes, paged_block_bytes,
                                    paged_slot_bytes)
from repro.serve.faults import InjectedFault, poison_logit_rows
from repro.serve.scheduler import (DONE, PREFILLING, RUNNING, WAITING,
                                   Request, Scheduler)


class _ImpossibleRequest(Exception):
    """Raised by the paged admission gate when a request's worst-case block
    need exceeds what the pool can ever satisfy. The engine decides whether
    that is a configuration error (pristine pool: fail fast with ValueError,
    as before) or graceful degradation (quarantine shrank capacity under a
    request that used to fit: retire it as ``failed``)."""

    def __init__(self, st, need: int):
        super().__init__(need)
        self.st = st
        self.need = need

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "GenResult",
           "ServeSummary", "prefill_bucket"]


def prefill_bucket(n: int, chunk_len: Optional[int] = None,
                   min_bucket: int = 8) -> int:
    """Padded length for a prefill chunk of ``n`` real tokens: the next
    power of two (>= ``min_bucket``), clamped to ``chunk_len`` when chunking
    is on. Admission compiles one prefill program per bucket instead of one
    per distinct prompt length."""
    assert n >= 1, n
    b = max(min_bucket, 1 << (n - 1).bit_length())
    if chunk_len is not None:
        assert n <= chunk_len, (n, chunk_len)
        b = min(b, chunk_len)
    return b


@dataclasses.dataclass
class GenResult:
    tokens: jax.Array
    ttft_s: float
    decode_s: float
    tokens_per_s: float


@dataclasses.dataclass
class ServeSummary:
    """Outcome of draining a request queue through the continuous engine.

    ``counters`` carries the occupancy/backpressure signals a future
    SLA-aware re-solve hook needs (ROADMAP): peak queue depth, blocked
    admissions, peak live tokens, and — under paging — block occupancy and
    the KV HBM actually pinned (``peak_kv_bytes``) vs the dense-slot cost
    (``dense_kv_bytes``).
    """
    results: dict                     # rid -> RequestResult
    n_steps: int                      # decode steps executed
    decode_s: float                   # wall time inside decode steps
    total_s: float                    # wall time of the whole drain
    tokens_per_s: float               # decode-produced tokens / decode_s
    counters: dict = dataclasses.field(default_factory=dict)

    def tokens_for(self, rid: int) -> np.ndarray:
        return self.results[rid].tokens


class ServeEngine:
    """One-shot batch serving: prefill + lock-step greedy decode.

    Prefill is length-bucketed for decoder-only LMs on plain token prompts:
    the prompt is padded to a power-of-two bucket and masked, so the compile
    cache is keyed by bucket (the same bucketed step the continuous engine
    uses in dense mode) instead of by distinct prompt length. Multimodal
    prefixes and encoder-decoder models keep the legacy per-length step.
    """

    def __init__(self, model, mp=None, mesh=None, donate: bool = True):
        self.model = model
        self.mp = as_assignment(mp)
        self.mesh = mesh
        self.prefill_step = get_serving_step(model, "prefill", mp=self.mp,
                                             donate=donate)
        self.decode_step = get_serving_step(model, "decode", mp=self.mp,
                                            donate=donate)
        self._bucketed = getattr(model, "supports_prefill_chunk", False)
        if self._bucketed:
            self.bucketed_prefill_step = get_serving_step(
                model, "bucketed_prefill", mp=self.mp, donate=donate)
        # compile-economy bookkeeping: which prefill programs this engine
        # needed vs how many distinct prompt lengths it served
        self.prefill_compile_keys: set = set()
        self.prompt_lens_seen: set = set()

    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0):
        # explicit capability check: enc-dec models declare that their cache
        # needs the encoder length (for pre-computed cross-attention K/V)
        # instead of the engine relying on call-arity coincidence
        if getattr(self.model, "cache_needs_enc_len", False):
            return self.model.init_cache(batch, max_len, enc_len)
        return self.model.init_cache(batch, max_len)

    def _prefill(self, params, caches, batch: dict):
        """Dispatch prefill: bucketed (compiled per power-of-two bucket) when
        the model supports it and the batch is plain tokens; the legacy
        per-length step otherwise. Returns (last-token logits, caches)."""
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        self.prompt_lens_seen.add(int(T0))
        Lb = prefill_bucket(T0)
        # legacy per-length step for multimodal/enc-dec batches, and for
        # prompts whose *bucket* reaches flash_min_seq: the bucketed step
        # never flashes (padding must not change the summation order), so
        # long prompts keep the flash-capable pre-bucketing path — and its
        # exact pre-bucketing numerics — at per-length compile cost
        if (not self._bucketed or "frames" in batch
                or batch.get("prefix_embeds") is not None
                or Lb >= getattr(self.model.cfg, "flash_min_seq", 1 << 30)):
            self.prefill_compile_keys.add(("legacy", int(T0)))
            return self.prefill_step(params, caches, batch)
        self.prefill_compile_keys.add(Lb)
        tok = jnp.pad(jnp.asarray(tokens, jnp.int32),
                      ((0, 0), (0, Lb - T0)))
        start = jnp.zeros((B,), jnp.int32)
        valid = jnp.full((B,), T0, jnp.int32)
        return self.bucketed_prefill_step(params, caches, tok, start, valid)

    def ttft(self, params, batch: dict, max_len: int, n_iters: int = 5,
             n_warmup: int = 2) -> float:
        """Median prefill wall time (the paper averages 5 iterations).

        Measures the *serving* prefill path: short prompts run the bucketed
        step, so the cost includes pow-2 bucket padding (that is what a
        deployment executes); prompts at or beyond flash_min_seq run the
        legacy unpadded flash-capable step, keeping long-context TTFT
        comparable with pre-bucketing measurements."""
        B = batch["tokens"].shape[0]
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        times = []
        for i in range(n_warmup + n_iters):
            caches = self.init_caches(B, max_len, enc_len)
            t0 = time.perf_counter()
            logits, caches = self._prefill(params, caches, batch)
            jax.block_until_ready(logits)
            if i >= n_warmup:
                times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    # ------------------------------------------------------------------
    def generate(self, params, batch: dict, max_new_tokens: int,
                 max_len: Optional[int] = None) -> GenResult:
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        prefix = 0
        if batch.get("prefix_embeds") is not None:
            prefix = batch["prefix_embeds"].shape[1]
        max_len = max_len or (T0 + prefix + max_new_tokens)
        caches = self.init_caches(B, max_len, enc_len)

        t0 = time.perf_counter()
        logits, caches = self._prefill(params, caches, batch)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t0

        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        t1 = time.perf_counter()
        pos = T0 + prefix
        for i in range(max_new_tokens - 1):
            logits, caches = self.decode_step(
                params, caches, out[-1][:, None], jnp.array(pos + i, jnp.int32))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        jax.block_until_ready(out[-1])
        dt = time.perf_counter() - t1
        toks = jnp.stack(out, axis=1)
        return GenResult(tokens=toks, ttft_s=ttft, decode_s=dt,
                         tokens_per_s=B * max_new_tokens / max(dt, 1e-9))


class ContinuousBatchingEngine:
    """Continuous batching over a fixed pool of cache slots.

    The drain loop alternates two phases per clock tick:

    1. *admission* — while a slot is free and the FCFS queue head has
       arrived, prefill it (batch=1), scatter its cache into the slot, and
       record its first greedy token + TTFT;
    2. *decode* — one compiled step over all ``n_slots`` rows with per-slot
       ``(B,)`` position and token vectors; finished requests release their
       slot, which the next tick's admission phase can immediately reuse.

    Vacant slots decode garbage rows; their outputs are ignored and their
    cache rows (dense) are fully reset at the next first-chunk prefill — or
    their writes land in the paged pool's trash block — so they cost FLOPs
    but never correctness.

    Prefill runs *in place* on the pool's caches with the decode batch
    width: each prefill-chunk step carries (tokens, start, valid) vectors
    over all ``n_slots`` rows, co-batching every prefilling slot whose next
    chunk shares a bucket while decoding/vacant rows pass through untouched
    (valid = 0). Paged mode writes the chunk straight into the slot's
    physical blocks (allocated incrementally per chunk); dense mode buckets
    whole prompts into the slot's ring. Compile cost is O(#buckets).

    ``chunk_len`` (paged only) splits prompts longer than the budget into
    fixed-size chunks; the step loop then interleaves at most
    ``chunk_budget`` chunk steps per decode step, so no decoding slot ever
    waits more than ``chunk_budget`` steps while a long prompt prefills
    (``ServeSummary.counters``: ``prefill_chunks``, ``decode_stall_steps``,
    ``max_decode_stall_run``, stall percentiles).

    ``paged_attn`` (paged only) selects the decode-attention implementation:
    ``"fused"`` (default) runs the Pallas paged-attention kernel directly
    over the block-major cache; ``"gather"`` keeps the reference
    gather-then-attend path. Greedy tokens are identical; the counters
    ``decode_attn_bytes_{read,fused_model,gather_model}`` expose the
    live-vs-capacity HBM-read gap between the two.

    ``prefix_cache`` (paged only; auto-on for pure-attention archs) shares
    KV blocks across requests: admission content-hashes the prompt block by
    block against a resident prefix index (chained digests — a match
    implies the whole prefix matches), maps matched blocks into the slot's
    table with a refcount bump, and starts prefill at the first unmatched
    token. Blocks are copy-on-write: a shared page in a chunk's write range
    is forked (device-side block copy) before the write, so a parent chain
    is never mutated. Freed refcount-0 indexed blocks park in a per-shard
    LRU and are reclaimed only under allocation pressure. Because the
    serving quant policy makes each token's K/V a pure function of the
    tokens at or before it, a cache hit is bit-exact: greedy tokens with
    sharing on equal sharing off.

    ``adaptive`` (an :class:`~repro.serve.adaptive.AdaptiveMPController`)
    closes the solver<->scheduler loop: once per tick, at the step boundary
    before admission, the engine feeds the controller its live counters
    (queue depth, cumulative blocked admissions, KV occupancy, decode-stall
    p99) and — when the controller's hysteresis says so — swaps every
    serving step to the plan for the new tau level via the
    ``get_serving_step`` memo (the MP assignment is part of the memo key:
    a swap is a dispatch switch, not a recompile) and invalidates the
    prefix index (quantized K/V bytes are plan-dependent). With no
    controller, or one that never fires, greedy tokens are bit-identical
    to a plain fixed-plan engine. ``ServeSummary.counters["adaptive"]``
    records the downshift/restore tallies and every swap's step/tau.

    ``chunk_len`` in *dense* mode switches prefill to the ring-aware
    chunked step over rings widened by ``chunk_len``
    (``init_cache(chunk_extra=...)``): a windowed ring sized exactly
    ``window`` truncates a chunk that straddles the window boundary when
    ``window`` is not chunk-aligned, so the widened ring keeps the current
    chunk plus a full window of context resident.

    ``preemption`` (paged only): when admission is gated on resources and
    the best arrived waiter has strictly higher ``Request.priority`` than a
    live request, the lowest-priority/latest-admitted slot is evicted back
    to the waiting queue (blocks freed — its prefix stays cached, so
    resume re-prefills nearly for free) and retried on the same tick. A
    resumed request re-prefills prompt + generated-so-far and continues;
    its tokens are identical to an uninterrupted run. At uniform priority
    nothing is ever evicted (pure FCFS backpressure, as before).
    """

    def __init__(self, model, n_slots: int = 4, max_len: int = 512,
                 mp=None, donate: bool = False, paged: bool = True,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 chunk_len: Optional[int] = None, chunk_budget: int = 1,
                 min_bucket: int = 8, paged_attn: Optional[str] = None,
                 mesh=None, prefix_cache: Optional[bool] = None,
                 preemption: bool = True, prefill_cobatch: bool = True,
                 adaptive=None, faults=None, max_retries: int = 1,
                 guardrail=None, kernel_fault_limit: int = 2):
        if getattr(model, "cache_needs_enc_len", False):
            raise NotImplementedError(
                "continuous batching currently serves decoder-only LMs")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        # load-adaptive MP: the controller owns the plan ladder; the engine
        # consults it once per tick (step boundary) and swaps the serving
        # steps through the get_serving_step memo on its say-so
        self.adaptive = adaptive
        if adaptive is not None:
            if mp is not None:
                raise ValueError(
                    "pass the base plan through the controller (its level-0 "
                    "tau), not both mp= and adaptive=")
            mp = adaptive.plan
        self.mp = as_assignment(mp)
        # the plan *object* (not just the assignment): the tau-anchored
        # guardrail reads its solved loss-MSE budget (tau^2 E[g^2])
        self._mp_plan = mp
        # fault tolerance: injector hooks (tests/CI), bounded per-request
        # retry budget through the resume machinery, the tau-anchored
        # numerical guardrail, and the kernel-fault count past which fused
        # paged attention degrades to the gather reference path
        self.faults = faults
        self.max_retries = int(max_retries)
        self.guardrail = guardrail
        self.kernel_fault_limit = int(kernel_fault_limit)
        assert self.max_retries >= 0, max_retries
        assert self.kernel_fault_limit >= 1, kernel_fault_limit
        if not paged and n_blocks is not None:
            raise ValueError("n_blocks only applies to paged mode; drop it "
                             "or remove paged=False")
        if paged_attn is not None and not paged:
            raise ValueError("paged_attn selects the paged decode-attention "
                             "implementation; drop it or remove paged=False")
        if paged_attn is None:
            paged_attn = "fused"
        if paged_attn not in ("fused", "gather"):
            raise ValueError(f"paged_attn must be 'fused' or 'gather', got "
                             f"{paged_attn!r}")
        self.paged_attn = paged_attn
        if chunk_len is not None:
            assert chunk_len >= 1, chunk_len
            ssm = getattr(model.cfg, "ssm", None)
            if ssm is not None and chunk_len % ssm.chunk != 0:
                raise ValueError(
                    f"chunk_len {chunk_len} must be a multiple of the SSD "
                    f"chunk ({ssm.chunk}): engine chunk boundaries must "
                    f"align with the SSD state recurrence for bit-exact "
                    f"resume (override cfg.ssm.chunk or pick another "
                    f"chunk_len)")
        assert chunk_budget >= 1, chunk_budget
        self.paged = paged
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.chunk_len = chunk_len
        self.chunk_budget = chunk_budget
        self.min_bucket = min_bucket
        # cross-request prefix caching: content-hash admitted prompts
        # against resident KV blocks and skip prefill for matched prefixes.
        # Auto-on for paged pure-attention archs; SSM/hybrid archs carry
        # slot-major state that a cached KV chain cannot reconstruct, so
        # they auto-disable (and asking explicitly is an error)
        if prefix_cache and not paged:
            raise ValueError("prefix_cache shares paged KV blocks; drop it "
                             "or remove paged=False")
        if paged:
            ssm_bytes = paged_slot_bytes(model, block_size)
            if prefix_cache and ssm_bytes > 0:
                raise ValueError(
                    "prefix_cache is unavailable for SSM/hybrid archs: "
                    "slot-major SSM state is not reconstructible from "
                    "shared KV blocks, so a matched prefix could not skip "
                    "prefill")
            if prefix_cache is None:
                prefix_cache = ssm_bytes == 0
        self.prefix_cache = bool(prefix_cache) and paged
        # preemption: under block pressure a strictly higher-priority
        # waiter evicts the lowest-priority live slot (paged only — resume
        # re-prefills the effective prompt, nearly free when its prefix is
        # still cached). At uniform priority nothing is ever evicted.
        self.preemption = bool(preemption) and paged
        # co-batch prefill chunks across buckets: pad every prefilling
        # slot's next chunk to the largest bucket and run ONE chunk step,
        # instead of one step per bucket group (padding is masked per row,
        # so numerics are unchanged)
        self.prefill_cobatch = bool(prefill_cobatch)
        # mesh-sharded serving: plan the layout once (pool geometry + page
        # sharding), compile mesh-aware steps, and resolve n_blocks so the
        # host allocator and the device layout agree
        self.mesh = mesh
        from repro.serve.parallel import make_serving_layout
        self.mesh_layout = make_serving_layout(
            mesh, n_slots=n_slots, max_len=max_len, block_size=block_size,
            n_blocks=n_blocks, paged=paged)
        if self.mesh_layout is not None and paged:
            self.n_blocks = self.mesh_layout.n_blocks
        # dense mode with chunk_len uses the ring-aware chunked step over
        # rings widened by chunk_len (chunk_extra), so a chunk straddling a
        # window boundary is never truncated
        self._prefill_kind = ("chunked_prefill" if paged else
                              ("dense_chunked_prefill" if chunk_len is not None
                               else "bucketed_prefill"))
        self._donate = donate
        self.prefill_chunk_step = get_serving_step(
            model, self._prefill_kind,
            mp=self.mp, mesh_layout=self.mesh_layout)
        self.decode_step = get_serving_step(
            model, "paged_decode" if paged else "decode", mp=self.mp,
            paged_attn=paged_attn if paged else None, donate=donate,
            mesh_layout=self.mesh_layout)
        # one pool per engine, persisted across serve() drains (the paged
        # prefix index survives between calls); built lazily by _make_pool
        self._pool = None
        # compile-economy bookkeeping (persists across serve() calls, like
        # the jit compile cache it mirrors)
        self.prefill_compile_keys: set = set()
        self.prompt_lens_seen: set = set()
        self._warned_flash = False
        # external control plane: cancel()/shutdown() may be called from any
        # thread (e.g. an on_token callback); the drain loop applies pending
        # control at the top of each tick, so cancellation is race-free with
        # respect to slot reuse
        self._ctl_lock = threading.Lock()
        self._cancel_pending: set = set()
        self._shutdown_flag = False

    # ------------------------------------------------------------------
    def cancel(self, rid: int) -> None:
        """Request cancellation of ``rid``. Thread-safe; takes effect at the
        next tick. The request retires with ``status="cancelled"`` keeping
        whatever tokens it had committed by then (possibly none)."""
        with self._ctl_lock:
            self._cancel_pending.add(rid)

    def shutdown(self) -> None:
        """Ask the current ``serve()`` drain to stop: every unfinished
        request is cancelled at the next tick, in-flight token transfers are
        drained, and ``serve()`` returns normally with partial results."""
        with self._ctl_lock:
            self._shutdown_flag = True

    # ------------------------------------------------------------------
    def _make_pool(self):
        """The engine's one pool, persisted across ``serve()`` drains so
        the paged prefix index (and its cached blocks) carries over: a
        prompt prefix indexed by one drain is a bit-exact cache hit in the
        next. Rebuilt only when a previous drain leaked slots (it errored
        mid-flight) — a clean drain frees every slot on the way out."""
        pool = self._pool
        if pool is not None and pool.n_free_slots == self.n_slots:
            pool.reset_counters()
            return pool
        if self.paged:
            pool = PagedCachePool(self.model, self.n_slots, self.max_len,
                                  block_size=self.block_size,
                                  n_blocks=self.n_blocks,
                                  mesh_layout=self.mesh_layout)
        else:
            pool = CachePool(self.model, self.n_slots, self.max_len,
                             mesh_layout=self.mesh_layout,
                             chunk_extra=self.chunk_len or 0)
        self._pool = pool
        return pool

    def _swap_plan(self, plan) -> None:
        """Apply a new MP plan at a step boundary: repoint the serving
        steps at the new assignment through the ``get_serving_step`` memo
        (the plan is part of the memo key, so a previously-seen plan is a
        dispatch switch, not a recompile) and invalidate the prefix index —
        quantized K/V bytes are plan-dependent, so blocks written under the
        old plan must not satisfy hits under the new one."""
        self.mp = as_assignment(plan)
        self._mp_plan = plan
        self.prefill_chunk_step = get_serving_step(
            self.model, self._prefill_kind, mp=self.mp,
            mesh_layout=self.mesh_layout)
        self.decode_step = get_serving_step(
            self.model, "paged_decode" if self.paged else "decode",
            mp=self.mp, paged_attn=self.paged_attn if self.paged else None,
            donate=self._donate, mesh_layout=self.mesh_layout)
        if self._pool is not None:
            self._pool.invalidate_prefix_index()

    def _digests(self, pool, st):
        """Chained prefix digests of the request's *effective* prompt
        (recomputed after a preemption — the generated tokens extend the
        chain, so a resumed request matches its own still-cached blocks)."""
        if not self.prefix_cache:
            return None
        if st.digests is None:
            st.digests = pool.prefix_digests(st.effective_tokens)
        return st.digests

    def _admit(self, params, pool, sched: Scheduler, now: int,
               evict=None, on_impossible=None) -> None:
        """Claim slots for admissible requests and emit prefill work items;
        no device work happens here — the step loop drives the chunks.

        ``evict`` (paged + preemption) is the engine's eviction hook: when
        the best arrived waiter is gated on resources and outranks a live
        request, the scheduler's victim is evicted (freeing its slot +
        blocks; its prefix blocks stay cached) and admission retries —
        bounded by the live-slot count, since every round removes one
        victim and equal priority never preempts.

        ``on_impossible`` handles a request whose worst-case block need no
        pool state can ever cover: when block quarantine shrank capacity
        under a request that fit the pristine pool, the serve loop retires
        it as ``failed`` instead of crashing the drain; a request that
        never fit stays the fail-fast ValueError it always was."""
        gate = None
        if self.paged:
            def gate(r):
                st = sched.states[r.rid]
                plen = st.effective_prompt_len
                mnew = st.remaining_new_tokens
                need = pool.blocks_for_request(plen, mnew)
                if need > pool.allocatable_blocks:
                    # would block the queue forever — surface it instead
                    raise _ImpossibleRequest(st, need)
                return pool.can_admit(plen, mnew,
                                      digests=self._digests(pool, st))
        while True:
            while pool.n_free_slots:
                try:
                    st = sched.pop_admissible(now, gate)
                except _ImpossibleRequest as exc:
                    if (on_impossible is not None
                            and pool.n_quarantined_blocks > 0
                            and exc.need <= pool.blocks_per_shard - 1):
                        on_impossible(exc.st)
                        continue
                    raise ValueError(
                        f"request {exc.st.request.rid} needs {exc.need} KV "
                        f"blocks but the pool has only "
                        f"{pool.allocatable_blocks}; raise --n-blocks or "
                        f"shrink the request") from None
                if st is None:
                    break
                req = st.request
                assert req.prompt_len + req.max_new_tokens <= self.max_len, (
                    f"request {req.rid}: {req.prompt_len}+"
                    f"{req.max_new_tokens} exceeds pool max_len "
                    f"{self.max_len}")
                self.prompt_lens_seen.add(req.prompt_len)
                # documented parity boundary, enforced with a one-time
                # warning: the chunked/bucketed step never flashes, so once
                # a chunk bucket reaches flash_min_seq, greedy tokens may
                # differ from a flash-capable one-shot reference in
                # low-order summation bits
                flash_min = getattr(self.model.cfg, "flash_min_seq", 1 << 30)
                biggest = min(req.prompt_len,
                              self.chunk_len or req.prompt_len)
                if (not self._warned_flash
                        and prefill_bucket(biggest, self.chunk_len,
                                           self.min_bucket) >= flash_min):
                    self._warned_flash = True
                    print(f"[serve] warning: prefill bucket >= "
                          f"flash_min_seq ({flash_min}); chunked prefill "
                          f"uses the reference attention path, so "
                          f"bit-parity with a flash one-shot reference is "
                          f"not guaranteed at these lengths")
                start_at = 0
                if self.paged:
                    # reservation only — blocks materialize chunk by chunk;
                    # matched prefix blocks are mapped in and skipped
                    slot = pool.alloc_slot(st.effective_prompt_len,
                                           st.remaining_new_tokens,
                                           digests=self._digests(pool, st))
                    start_at = pool.matched_tokens(slot)
                else:
                    slot = pool.alloc()
                sched.start_prefill(st, slot, now, start_at=start_at)
                if st.wall_admitted == 0.0:   # resumed: keep first admission
                    st.wall_admitted = time.perf_counter()
            if evict is None:
                return
            cand = sched.peek_admissible(now)
            if cand is None:
                return
            victim = sched.preempt_candidate(cand.request.priority)
            if victim is None:
                return
            if not evict(victim):
                return

    def _prefill_tick(self, params, pool, sched: Scheduler, now: int):
        """Run one compiled prefill-chunk step: co-batch the next chunk of
        every prefilling slot — across buckets, padded to the largest one
        (``prefill_cobatch``), or the legacy same-bucket-as-head group —
        over the full ``n_slots`` batch (inactive rows pass through with
        valid = 0). Chunk order is priority, then shortest remaining
        prefill.

        Returns ``(dt, nxt_dev, flag_dev, finished, n_tokens,
        alloc_failed)``: the step's dispatch wall time, the (n_slots,)
        *device* greedy-token vector (no host readback — delivery is the
        caller's job) plus its non-finite tripwire flag vector, the list of
        ``(slot, state)`` pairs whose prompt completed this tick (their
        next token is row ``slot`` of ``nxt_dev``; its ``out_tokens`` entry
        holds a ``None`` placeholder until the value lands on the host),
        the real prompt tokens processed, and the states whose page
        allocation failed this tick (dropped from the step; the caller
        contains them). ``nxt_dev`` is None when every candidate's
        allocation failed — no step ran."""
        cands = []
        for slot, st in sched.prefilling.items():
            start = st.prefill_pos
            take = st.effective_prompt_len - start
            if self.chunk_len is not None:
                take = min(take, self.chunk_len)
            cands.append((slot, st, start, take))
        # priority classes first, then shortest-remaining-prefill-first:
        # the prompt closest to producing its first token (and freeing
        # chunk bandwidth) goes first — with prefix caching, a mostly
        # cached prompt has a tiny remainder and jumps the line
        cands.sort(key=lambda c: (-c[1].request.priority,
                                  c[1].effective_prompt_len - c[1].prefill_pos,
                                  c[0]))
        # materialize each candidate's pages first (a borrowed page in the
        # write range is COW-forked here): a per-slot allocation failure —
        # injected, or organic under quarantine pressure — drops only that
        # slot from the step, never the whole tick
        alloc_failed = []
        if self.paged:
            ok = []
            for slot, st, start, take in cands:
                try:
                    if self.faults is not None:
                        self.faults.on_alloc(slot)
                    pool.ensure_range(slot, start, start + take)
                except (InjectedFault, RuntimeError):
                    alloc_failed.append(st)
                    continue
                ok.append((slot, st, start, take))
            cands = ok
            if not cands:
                return 0.0, None, None, [], 0, alloc_failed
        if self.prefill_cobatch:
            # co-batch across buckets: pad every slot's chunk to the
            # largest bucket and run one step (per-row start/valid mask the
            # padding, so smaller rows' numerics are unchanged)
            items = cands
            bucket = max(prefill_bucket(take, self.chunk_len,
                                        self.min_bucket)
                         for _, _, _, take in items)
        else:
            # legacy: one bucket group per chunk step (the head's bucket)
            items, bucket = [], None
            for slot, st, start, take in cands:
                b = prefill_bucket(take, self.chunk_len, self.min_bucket)
                if bucket is None:
                    bucket = b
                if b == bucket:
                    items.append((slot, st, start, take))
        self.prefill_compile_keys.add(bucket)
        tok = np.zeros((self.n_slots, bucket), np.int32)
        start_v = np.ones((self.n_slots,), np.int32)   # >0: leave row alone
        valid_v = np.zeros((self.n_slots,), np.int32)  # 0: inactive row
        for slot, st, start, take in items:
            tok[slot, :take] = np.asarray(st.effective_tokens,
                                          np.int32)[start:start + take]
            start_v[slot] = start
            valid_v[slot] = take
        t0 = time.perf_counter()
        if self.paged:
            logits, pool.caches = self.prefill_chunk_step(
                params, pool.caches, jnp.asarray(tok), jnp.asarray(start_v),
                jnp.asarray(valid_v), pool.block_tables_device())
        else:
            logits, pool.caches = self.prefill_chunk_step(
                params, pool.caches, jnp.asarray(tok), jnp.asarray(start_v),
                jnp.asarray(valid_v))
        nxt_dev = greedy_next_token(logits)
        flag_dev = nonfinite_rows(logits)
        dt = time.perf_counter() - t0
        if self.paged and self.prefix_cache:
            # index the blocks this chunk filled (after dispatch: any
            # future matcher's chunks are dispatched later on the same
            # device stream, so they order after these writes)
            for slot, st, start, take in items:
                pool.register_prefix(slot, start + take)
        finished = []
        n_prefill_tokens = sum(take for _, _, _, take in items)
        for slot, st, start, take in items:
            st = sched.prefill_advance(slot, take, dt)
            if st.prefill_pos == st.effective_prompt_len:
                st = sched.finish_prefill(slot, None, now)
                finished.append((slot, st))
        return dt, nxt_dev, flag_dev, finished, n_prefill_tokens, alloc_failed

    def serve(self, params, requests: Sequence[Request], *,
              sync: bool = False,
              on_token: Optional[Callable[[int, int, int], None]] = None,
              max_in_flight: int = 8) -> ServeSummary:
        """Drain ``requests`` (any arrival order) and return all results.

        The drain is a producer/consumer pipeline by default: the main
        thread dispatches device steps and enqueues each step's *device*
        token vector plus its host bookkeeping (which request gets which
        row), and a consumer thread turns queued vectors into host values —
        one batched ``jax.device_get`` per wakeup — filling each request's
        token list and firing ``on_token(rid, idx, token)``. The producer
        schedules purely by token *counts* (every request runs exactly
        ``max_new_tokens`` steps), so it never needs a token value and the
        per-step host readback disappears from the decode critical path;
        the device runs up to ``max_in_flight`` steps ahead of the host.

        ``sync=True`` keeps the legacy lockstep loop — every step's tokens
        are read back (and ``on_token`` fired) before the next step is
        dispatched — for readback-cost comparisons and the parity matrix.
        Both modes run the *same* device schedule and the same on-device
        argmax, so greedy tokens are bit-identical between them.

        ``on_token`` fires on the consumer thread in async mode (in
        submission order per request) and inline in sync mode; an exception
        it raises cancels the remaining requests, drains in-flight
        transfers, and re-raises from ``serve()``. :meth:`cancel`,
        :meth:`shutdown` and ``Request.timeout_steps`` take effect at tick
        granularity; cancelled/timed-out requests keep the tokens they had
        committed (``RequestResult.status`` records the outcome).
        """
        assert max_in_flight >= 1, max_in_flight
        if self.mesh is not None:
            from repro.serve.parallel import shard_serving_params
            params = shard_serving_params(self.model, params, self.mesh)
        pool = self._make_pool()
        sched = Scheduler()
        with self._ctl_lock:
            self._cancel_pending.clear()
            self._shutdown_flag = False
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            sched.submit(r)

        retired: list = []                 # RequestState, retirement order
        # device-resident decode input; rows refresh via on-device merges
        # (first tokens) and argmax outputs — never from the host. Vacant
        # rows hold stale tokens: their writes go to the trash block (paged)
        # or to a row the next first-chunk prefill fully resets (dense).
        cur_tok = jnp.zeros((self.n_slots, 1), jnp.int32)
        now = 0
        n_steps = 0
        decode_s = 0.0
        host_blocked_s = 0.0
        drain_wait_s = 0.0
        n_readbacks = 0
        readback_sizes: list = []
        inflight_peak = 0
        t_first_decode = None
        peak_queue = peak_live = peak_blocks = peak_slots = 0
        # per-decode-step attention HBM read model (paged): the fused kernel
        # fetches each running row's live pages (plus at most one trash-block
        # fetch per row whose tail pages are dead — consecutive dead pages
        # revisit block 0 and their copies are elided); the gather path
        # materializes every table slot of every row, so its traffic scales
        # with provisioned capacity
        attn_pages_fused = attn_pages_gather = live_token_steps = 0
        prefill_chunks = decode_stall_steps = max_stall_run = stall_run = 0
        prefill_tokens = 0
        stall_s_run = 0.0
        stall_s: list = []            # per-decode-step injected prefill time
        adaptive_swaps: list = []     # plan swaps applied this drain
        # ---- fault tolerance bookkeeping ----
        inj = self.faults
        grail = self.guardrail
        faults_seen: dict = {}        # containment events by fault kind
        faults_contained = faults_failed = fault_retries = 0
        kernel_faults = 0             # step exceptions + hung steps
        degraded = False              # fused paged attention -> gather
        poison_watch: set = set()     # slots with an injected NaN in flight
        last_fault_error: Optional[BaseException] = None
        guardrail_swaps: list = []    # forced restores (numerical breach)

        def consult_adaptive():
            """Feed the controller this tick's counters; apply any swap.
            Runs exactly once per tick at the step boundary (before
            admission), so a swap can never land mid-step."""
            if self.paged:
                cap = pool.n_blocks - pool.n_shards
                occ = pool.blocks_in_use / max(cap, 1)
            else:
                occ = 1.0 - pool.n_free_slots / self.n_slots
            if stall_s:
                srt = np.sort(np.asarray(stall_s[-256:], np.float64))
                p99 = float(srt[min(len(srt) - 1, int(0.99 * len(srt)))])
            else:
                p99 = 0.0
            newplan = self.adaptive.observe(
                now, queue_depth=sched.queue_depth,
                blocked=sched.blocked_admissions,
                occupancy=occ, stall_p99=p99)
            if newplan is not None:
                self._swap_plan(newplan)
                adaptive_swaps.append({"step": int(now),
                                       "level": self.adaptive.level,
                                       "tau": self.adaptive.tau})

        # ---- host-side delivery plumbing (shared by both modes) ----
        q: "queue.Queue" = queue.Queue(maxsize=max_in_flight)
        consumer_err: list = []

        def deliver(arr, flags, deliveries):
            """Fill each (state, idx, slot) placeholder from a host token
            vector, check its non-finite tripwire flag, and fire the
            streaming callback."""
            t_now = time.perf_counter()
            for st, idx, slot in deliveries:
                tok = int(arr[slot])
                st.out_tokens[idx] = tok
                if (flags is not None and bool(flags[slot])
                        and st.fault_idx is None):
                    # device-side tripwire: the logit row that produced this
                    # token held NaN/inf. Stamp the first poisoned index;
                    # the producer contains the request at the next tick
                    # boundary (tokens before idx stay good).
                    st.fault_idx = idx
                    st.fault_kind = "nonfinite_logits"
                if idx == 0:
                    # honest TTFT, stamped at *delivery*: wall time from
                    # admission until the first token value landed on the
                    # host — under async that includes any pipeline lag,
                    # which is exactly what a streaming client experiences
                    st.ttft_s = t_now - st.wall_admitted
                if inj is not None:
                    try:
                        inj.on_deliver(st.request.rid, slot)
                    except InjectedFault:
                        # injected consumer error: contained per-request —
                        # the pinned user-callback contract (cancel all and
                        # re-raise) applies to *user* exceptions only
                        if st.fault_idx is None:
                            st.fault_idx = idx
                            st.fault_kind = "consumer_error"
                        continue
                suppressed = (st.fault_idx is not None
                              and idx >= st.fault_idx)
                if on_token is not None and not consumer_err and not suppressed:
                    on_token(st.request.rid, idx, tok)

        def consume():
            nonlocal n_readbacks
            stop = False
            while not stop:
                item = q.get()
                if item is None:
                    return
                batch = [item]
                while True:    # greedy drain: one device_get per wakeup
                    try:
                        more = q.get_nowait()
                    except queue.Empty:
                        break
                    if more is None:
                        stop = True
                        break
                    batch.append(more)
                arrs = jax.device_get([(tok, flg) for tok, flg, _ in batch])
                n_readbacks += 1
                readback_sizes.append(len(batch))
                for (_, _, dl), (arr, flg) in zip(batch, arrs):
                    try:
                        deliver(arr, flg, dl)
                    except BaseException as e:  # noqa: BLE001
                        # keep draining so the producer never deadlocks on a
                        # full queue; re-raised from serve() after the join
                        consumer_err.append(e)

        def consume_guarded():
            try:
                consume()
            except BaseException as e:  # noqa: BLE001 — e.g. device_get died
                consumer_err.append(e)
                while q.get() is not None:      # unblock producer until STOP
                    pass

        consumer = None
        if not sync:
            consumer = threading.Thread(target=consume_guarded,
                                        name="serve-consumer", daemon=True)
            consumer.start()

        def emit(nxt_dev, flag_dev, deliveries):
            nonlocal host_blocked_s, n_readbacks, inflight_peak
            if sync:
                t0 = time.perf_counter()
                arr = np.asarray(nxt_dev)   # blocks on the device step
                flg = None if flag_dev is None else np.asarray(flag_dev)
                host_blocked_s += time.perf_counter() - t0
                n_readbacks += 1
                readback_sizes.append(1)
                try:
                    deliver(arr, flg, deliveries)
                except BaseException as e:  # noqa: BLE001 — user on_token
                    # same graceful shutdown as async mode: record the
                    # error, finish the drain (slots freed, pool books
                    # settled and reconciled), re-raise after
                    consumer_err.append(e)
            else:
                t0 = time.perf_counter()
                # blocks only at max_in_flight
                q.put((nxt_dev, flag_dev, deliveries))
                host_blocked_s += time.perf_counter() - t0
                inflight_peak = max(inflight_peak, q.qsize())

        # ---- preemption: evict a live slot back to the waiting queue ----
        def evict(st):
            # the consumer may still be landing this slot's token values;
            # resume re-prefills prompt + generated-so-far, so every
            # committed placeholder must hold a real value first
            while any(t is None for t in st.out_tokens):
                if consumer_err:
                    return False  # shutting down; stop preempting
                time.sleep(2e-4)
            # freeing while earlier steps are in flight is safe: any reuse
            # of these blocks is written by a later-dispatched step, and
            # the device executes dispatches in order
            pool.free_slot(st.slot)
            sched.preempt(st, now)
            return True

        # ---- fault containment ----
        def flush_placeholders(st):
            """Wait out the consumer's in-flight deliveries for one state:
            retry resumes from prompt + tokens-so-far, so every committed
            placeholder must hold a real value before truncation. False on
            shutdown (consumer error) — nothing more will land."""
            while any(t is None for t in st.out_tokens):
                if consumer_err:
                    return False
                if sync:
                    # sync delivers inline; a residual None means the emit
                    # that would have filled it never ran — unreachable
                    # outside shutdown, but never spin on it
                    return False
                time.sleep(2e-4)
            return True

        def maybe_degrade():
            """Past ``kernel_fault_limit`` step faults, fall back from the
            fused paged-attention kernel to the gather reference path: a
            dispatch switch through the ``get_serving_step`` memo (the key
            includes ``paged_attn``), never a mid-drain recompile — and the
            parity matrix pins fused/gather greedy tokens bit-identical, so
            the degraded drain's tokens don't change."""
            nonlocal degraded
            if (not degraded and self.paged and self.paged_attn == "fused"
                    and kernel_faults >= self.kernel_fault_limit):
                degraded = True
                self.paged_attn = "gather"
                self.decode_step = get_serving_step(
                    self.model, "paged_decode", mp=self.mp,
                    paged_attn="gather", donate=self._donate,
                    mesh_layout=self.mesh_layout)

        def contain(st, kind=None, quarantine=None):
            """Contain one faulted request: settle its in-flight
            deliveries, truncate its tokens to the last-known-good prefix,
            quarantine its KV pages when the fault may have poisoned them,
            and either requeue it for a bounded retry (re-prefilling prompt
            + surviving tokens through the bit-exact resume path, so a
            retried request that completes matches a fault-free run) or
            retire it ``failed`` with the partial tokens."""
            nonlocal faults_contained, faults_failed, fault_retries
            if st.status == WAITING:
                return              # already contained this sweep
            kind = kind or st.fault_kind or "fault"
            if not flush_placeholders(st):
                return              # shutting down; apply_control retires
            was_done = st.status == DONE
            if was_done and st.result_status not in ("ok", "retried"):
                return              # cancelled/timed out: terminal
            faults_seen[kind] = faults_seen.get(kind, 0) + 1
            if st.fault_idx is not None:
                # drop the poisoned suffix (placeholders included — flush
                # guaranteed values landed, truncation regrows the step debt
                # through remaining_new_tokens)
                del st.out_tokens[st.fault_idx:]
            if quarantine is None:
                quarantine = kind in ("nonfinite_logits", "nan_page")
            if st.status in (PREFILLING, RUNNING):
                if st.slot in poison_watch:
                    # an injected NaN is in flight for this slot: whatever
                    # fault got here first (alloc failure, step exception),
                    # its pages are poisoned — releasing them to the free
                    # list would leak the NaN into reallocated requests
                    quarantine = True
                poison_watch.discard(st.slot)
                if self.paged and quarantine:
                    # the slot's pages may hold NaN/inf: pull every one out
                    # of circulation (de-indexed, COW-forked away from any
                    # borrower, never returned to the free list)
                    pool.quarantine_slot(st.slot)
                pool.free_slot(st.slot)
            retry = (st.n_retries < self.max_retries
                     and kind != "consumer_error")
            if retry:
                if was_done:
                    # the flag landed after deadline retirement: un-retire
                    # and redo the poisoned tail
                    retired.remove(st)
                sched.requeue_for_retry(st, now)
                fault_retries += 1
                faults_contained += 1
            else:
                faults_failed += 1
                st.fault_kind = kind
                if was_done:
                    st.result_status = "failed"
                else:
                    retired.append(sched.retire(st, now, "failed"))

        def apply_faults():
            """Producer-side containment sweep, run at tick boundaries:
            contain every request the consumer's tripwire (or an injected
            delivery fault) has stamped since the last sweep."""
            hit = [st for st in sched.states.values()
                   if st.fault_idx is not None and st.status != WAITING]
            for st in hit:
                contain(st)

        def impossible(st):
            """Quarantine shrank the pool below this request's worst-case
            block need: fail it gracefully instead of crashing the drain."""
            nonlocal faults_failed
            faults_seen["impossible_request"] = (
                faults_seen.get("impossible_request", 0) + 1)
            faults_failed += 1
            sched.remove_waiting(st.request.rid)
            retired.append(sched.retire(st, now, "failed"))

        # ---- control plane: cancellation / timeouts / shutdown ----
        def cancel_live(st, status, now):
            if st.status == WAITING:
                sched.remove_waiting(st.request.rid)
            elif st.status in (PREFILLING, RUNNING):
                pool.free_slot(st.slot)
            retired.append(sched.retire(st, now, status))

        def apply_control(now):
            with self._ctl_lock:
                todo = self._cancel_pending
                self._cancel_pending = set()
                shutdown = self._shutdown_flag
            # a callback error is an implicit shutdown: stop scheduling new
            # work, drain what's in flight, re-raise after the join
            shutdown = shutdown or bool(consumer_err)
            for st in list(sched.states.values()):
                t = st.request.timeout_steps
                if (st.status != DONE and t is not None
                        and now >= st.request.arrival + t):
                    cancel_live(st, "timeout", now)
            if shutdown:
                todo = set(sched.states)
            for rid in sorted(todo):
                st = sched.states.get(rid)
                if st is not None and st.status != DONE:
                    cancel_live(st, "cancelled", now)

        t_start = time.perf_counter()
        try:
            while True:
                if not sched.has_work():
                    # drain-end pipeline flush: a tripwire flag still in
                    # flight can re-queue a retry — settle every in-flight
                    # delivery, sweep once more, and only then stop
                    for st in list(retired):
                        flush_placeholders(st)
                    apply_faults()
                    if not sched.has_work():
                        break
                apply_control(now)
                if not sched.has_work():
                    continue
                if inj is not None:
                    inj.tick(now)
                apply_faults()
                if not sched.has_work():
                    continue
                if self.adaptive is not None:
                    consult_adaptive()
                self._admit(params, pool, sched, now,
                            evict if self.preemption else None,
                            on_impossible=impossible)
                peak_queue = max(peak_queue, sched.queue_depth)
                # prefill phase — TTFT-aware arbitration: prefill freely
                # while nothing is decoding, else at most chunk_budget chunk
                # steps per decode step so no decode slot stalls unboundedly
                chunks_this_tick = 0
                while sched.prefilling and (not sched.running
                                            or chunks_this_tick
                                            < self.chunk_budget):
                    was_decoding = bool(sched.running)
                    try:
                        if (inj is not None
                                and inj.on_step("prefill") == "hung"):
                            kernel_faults += 1
                            maybe_degrade()
                        (dt, nxt_dev, flag_dev, finished, n_tok,
                         alloc_failed) = self._prefill_tick(
                            params, pool, sched, now)
                    except InjectedFault as e:
                        # step blew up before any cache write: contain every
                        # prefilling slot (bounded retry re-prefills from
                        # scratch, so no page can be half-written)
                        last_fault_error = e
                        kernel_faults += 1
                        maybe_degrade()
                        for st in list(sched.prefilling.values()):
                            contain(st, kind="step_exception",
                                    quarantine=False)
                        chunks_this_tick += 1
                        continue
                    for st in alloc_failed:
                        contain(st, kind="alloc_failure", quarantine=False)
                    if nxt_dev is None:     # every candidate's alloc failed
                        chunks_this_tick += 1
                        continue
                    prefill_chunks += 1
                    prefill_tokens += n_tok
                    chunks_this_tick += 1
                    if was_decoding:
                        decode_stall_steps += 1
                        stall_run += 1
                        max_stall_run = max(max_stall_run, stall_run)
                        stall_s_run += dt
                    if finished:
                        # scatter first tokens into the device-resident
                        # decode input; ship the same vector to the host
                        # for delivery
                        mask = np.zeros((self.n_slots,), bool)
                        deliveries = []
                        for slot, st in finished:
                            mask[slot] = True
                            # resumed requests already hold delivered tokens;
                            # the placeholder finish_prefill appended is the
                            # last entry, not necessarily index 0
                            deliveries.append(
                                (st, len(st.out_tokens) - 1, slot))
                        cur_tok = merge_first_tokens(cur_tok, nxt_dev,
                                                     jnp.asarray(mask))
                        emit(nxt_dev, flag_dev, deliveries)
                        for slot, st in finished:
                            if st.done:          # max_new_tokens == 1
                                retired.append(sched.retire(st, now))
                                pool.free_slot(slot)
                    # a finished 1-token request frees its slot immediately:
                    # let a queued request claim it this same tick
                    self._admit(params, pool, sched, now,
                                evict if self.preemption else None,
                                on_impossible=impossible)
                if sched.running:
                    # fresh array every tick: jnp.asarray may be zero-copy
                    # on CPU, and an in-flight step from a previous tick
                    # could still alias a reused buffer we'd be zeroing
                    pos_host = np.zeros((self.n_slots,), np.int32)
                    alloc_bad = []
                    for slot, st in sched.running.items():
                        pos_host[slot] = st.next_pos
                        if self.paged:
                            try:
                                if inj is not None:
                                    inj.on_alloc(slot)
                                pool.ensure_block(slot, st.next_pos)
                            except (InjectedFault, RuntimeError) as e:
                                last_fault_error = e
                                alloc_bad.append(st)
                    for st in alloc_bad:
                        contain(st, kind="alloc_failure", quarantine=False)
                    if not sched.running:   # everyone's page alloc failed
                        now += 1
                        continue
                    # live tokens after this step: everything written so far
                    # (next_pos) plus the write this step performs
                    live_now = sum(st.next_pos + 1
                                   for st in sched.running.values())
                    peak_live = max(peak_live, live_now)
                    peak_slots = max(peak_slots, len(sched.running))
                    if self.paged:
                        peak_blocks = max(peak_blocks, pool.blocks_in_use)
                        live_token_steps += live_now
                        pages = {s: -(-(st.next_pos + 1) // pool.block_size)
                                 for s, st in sched.running.items()}
                        attn_pages_fused += sum(pages.values()) + sum(
                            1 for s in range(self.n_slots)
                            if pages.get(s, 0) < pool.max_blocks)
                        attn_pages_gather += self.n_slots * pool.max_blocks
                    bt = None
                    if self.paged:
                        # decode sees block tables only for *running* rows:
                        # a slot mid-prefill owns real blocks, and the
                        # vacant-row garbage write must go to the trash
                        # block, not into K/V its earlier chunks wrote
                        bt_host = pool.block_tables.copy()
                        for s in range(self.n_slots):
                            if s not in sched.running:
                                bt_host[s] = -1
                        bt = jnp.asarray(bt_host)
                    # injected numeric poisons for this step: a NaN'd KV
                    # page is written *before* dispatch (the step reads it
                    # back through attention), a NaN'd logit row is applied
                    # to the step's output below
                    nan_rows = None
                    if inj is not None:
                        for spec in inj.take_poisons():
                            slots = sorted(sched.running)
                            slot = (spec.slot if spec.slot in sched.running
                                    else slots[0])
                            if spec.kind == "nan_page" and self.paged:
                                row = pool.block_tables[slot]
                                live = [int(b) for b in row
                                        if int(b) >= 0
                                        and int(b) % pool.blocks_per_shard]
                                if live:
                                    blk = live[min(spec.page, len(live) - 1)]
                                    pool.poison_block(blk)
                                    poison_watch.add(slot)
                            else:   # nan_logits (nan_page degrades to it
                                    # in dense mode — no pages to poison)
                                if nan_rows is None:
                                    nan_rows = np.zeros((self.n_slots,),
                                                        bool)
                                nan_rows[slot] = True
                                poison_watch.add(slot)
                    shadow = None
                    if (grail is not None and self.mp
                            and grail.restored_at is None
                            and n_steps % grail.every == 0):
                        # tau-anchored shadow: one high-precision decode
                        # step over the same inputs before the real step
                        # touches them (donate=False — its cache writes are
                        # discarded), MSE'd below against the active plan's
                        # logits for one sampled live row
                        rows = sorted(sched.running)
                        grail_row = rows[(n_steps // grail.every)
                                         % len(rows)]
                        ref_step = get_serving_step(
                            self.model,
                            "paged_decode" if self.paged else "decode",
                            mp=None,
                            paged_attn=(self.paged_attn if self.paged
                                        else None),
                            donate=False, mesh_layout=self.mesh_layout)
                        if self.paged:
                            s_logits, _ = ref_step(
                                params, pool.caches, cur_tok,
                                jnp.asarray(pos_host), bt)
                        else:
                            s_logits, _ = ref_step(
                                params, pool.caches, cur_tok,
                                jnp.asarray(pos_host))
                        shadow = (s_logits, grail_row)
                    t0 = time.perf_counter()
                    if t_first_decode is None:
                        t_first_decode = t0
                    try:
                        if (inj is not None
                                and inj.on_step("decode") == "hung"):
                            kernel_faults += 1
                            maybe_degrade()
                        if self.paged:
                            logits, pool.caches = self.decode_step(
                                params, pool.caches, cur_tok,
                                jnp.asarray(pos_host), bt)
                        else:
                            logits, pool.caches = self.decode_step(
                                params, pool.caches, cur_tok,
                                jnp.asarray(pos_host))
                    except InjectedFault as e:
                        # the step never dispatched — caches are intact;
                        # contain every running request (bounded retry
                        # re-prefills prompt + tokens-so-far)
                        last_fault_error = e
                        kernel_faults += 1
                        maybe_degrade()
                        for st in list(sched.running.values()):
                            contain(st, kind="step_exception",
                                    quarantine=False)
                        now += 1
                        continue
                    if shadow is not None:
                        s_logits, grail_row = shadow
                        # fp32 logit MSE for the sampled row — one blocking
                        # scalar readback per `every` steps. A NaN MSE (a
                        # poison fault, not a quantization breach) never
                        # trips the comparison.
                        mse = float(shadow_logit_mse(logits, s_logits,
                                                     grail_row))
                        budget = grail.budget_for(self._mp_plan)
                        if grail.observe_mse(now, mse, budget):
                            # measured loss-MSE breached margin * budget —
                            # eq. 23's tau constraint, enforced live: force
                            # a restore to the base plan at this boundary
                            if self.adaptive is not None:
                                self._swap_plan(
                                    self.adaptive.force_restore(now))
                            else:
                                self._swap_plan(None)
                            guardrail_swaps.append(
                                {"step": int(now), "mse": mse,
                                 "budget": budget})
                    if nan_rows is not None and nan_rows.any():
                        logits = poison_logit_rows(logits,
                                                   jnp.asarray(nan_rows))
                    nxt_dev = greedy_next_token(logits)
                    flag_dev = nonfinite_rows(logits)
                    cur_tok = nxt_dev[:, None]
                    deliveries = []
                    for slot in list(sched.running):
                        st = sched.running[slot]
                        deliveries.append((st, len(st.out_tokens), slot))
                        sched.record_token(slot, None)
                    emit(nxt_dev, flag_dev, deliveries)
                    decode_s += time.perf_counter() - t0
                    n_steps += 1
                    stall_s.append(stall_s_run)
                    stall_s_run = 0.0
                    stall_run = 0
                    # deadline-based retirement: a request is done after
                    # exactly max_new_tokens scheduled steps — the host
                    # never inspects token values to decide
                    for slot in list(sched.running):
                        st = sched.running[slot]
                        if st.done:
                            if slot in poison_watch:
                                # an injected NaN targeted this row: settle
                                # its deliveries now so the tripwire flag
                                # cannot lose the race against deadline
                                # retirement (which frees the very pages
                                # quarantine must capture)
                                poison_watch.discard(slot)
                                flush_placeholders(st)
                                if st.fault_idx is not None:
                                    contain(st)
                                    continue
                            retired.append(sched.retire(st, now))
                            pool.free_slot(slot)
                    now += 1
                elif not sched.prefilling:
                    # idle: jump the clock to the next arrival, don't spin
                    nxt_arrival = sched.next_arrival()
                    if nxt_arrival is None:
                        break
                    now = max(now + 1, nxt_arrival)
        finally:
            if consumer is not None:
                # drain: everything emitted gets delivered before we return.
                # Counted separately from host_blocked_s — this wait overlaps
                # no dispatchable work (the schedule is complete), so it is
                # not critical-path blocking, just the pipeline emptying
                t0 = time.perf_counter()
                q.put(None)
                consumer.join()
                drain_wait_s += time.perf_counter() - t0

        t_drain_end = time.perf_counter()
        total_s = t_drain_end - t_start
        if consumer_err:
            if self.paged:
                # a callback error aborts mid-flight: slots were freed by
                # the shutdown cancellations, but a delivery that died
                # half-way can leave refcounts ahead of the tables — settle
                # the books so the pool is reusable after the re-raise
                pool.reconcile()
            raise consumer_err[0]
        if not sync and t_first_decode is not None:
            # async decode_s: the producer only measured dispatch time, so
            # report the wall span from the first decode dispatch to drain
            # end (device compute, interleaved prefill, and overlapped
            # readbacks) — the honest denominator for pipelined throughput
            decode_s = max(decode_s, t_drain_end - t_first_decode)
        results = {st.request.rid: sched.materialize(st) for st in retired}
        # decode-produced tokens (each request's first token is prefill's)
        n_decoded = sum(max(len(r.tokens) - 1, 0) for r in results.values())
        counters = {
            "paged": self.paged,
            "mesh": (None if self.mesh_layout is None else
                     {"data": self.mesh_layout.data,
                      "model": self.mesh_layout.model,
                      "shard_pages": self.mesh_layout.shard_pages}),
            # wall-clock throughput over the *identical* window in sync and
            # async modes (submission to drain end) — the fair pipelined-vs-
            # sync comparison; ``tokens_per_s`` keeps the decode-phase-only
            # denominator, which is measured differently in the two modes
            "wall_tokens_per_s": (n_decoded / total_s if total_s > 0 else 0.0),
            "peak_queue_depth": peak_queue,
            "blocked_admissions": sched.blocked_admissions,
            "peak_live_tokens": peak_live,
            "peak_slots_in_use": peak_slots,
            "dense_kv_bytes": self.n_slots * dense_slot_bytes(self.model,
                                                              self.max_len),
            # chunked/bucketed prefill economics + decode-stall signals
            "prefill_chunks": prefill_chunks,
            "prefill_tokens": prefill_tokens,
            "prefill_cobatch": bool(self.prefill_cobatch),
            # priority scheduling: evictions back to the waiting queue
            "preemptions": sched.preemptions,
            "decode_stall_steps": decode_stall_steps,
            "max_decode_stall_run": max_stall_run,
            "prefill_buckets": len(self.prefill_compile_keys),
            "distinct_prompt_lens": len(self.prompt_lens_seen),
            # host/device overlap: how long the producer thread sat blocked
            # on token transfers *on the decode critical path* (sync: every
            # step's readback; async: queue backpressure only — the final
            # drain is drain_wait_s, overlapping no dispatchable work), how
            # readbacks batched, and how far the device ran ahead of the host
            "sync": bool(sync),
            "host_blocked_s": host_blocked_s,
            "host_blocked_s_per_step": host_blocked_s / max(n_steps, 1),
            "drain_wait_s": drain_wait_s,
            "n_readbacks": n_readbacks,
            "readback_batch_max": int(max(readback_sizes, default=0)),
            "readback_batch_mean": (float(np.mean(readback_sizes))
                                    if readback_sizes else 0.0),
            "steps_in_flight_peak": inflight_peak,
            "n_cancelled": sum(1 for st in retired
                               if st.result_status in ("cancelled",
                                                       "timeout")),
            "n_failed": sum(1 for st in retired
                            if st.result_status == "failed"),
            "n_retried": sum(1 for st in retired
                             if st.result_status == "retried"),
        }
        counters["faults"] = {
            "injected": dict(inj.fired) if inj is not None else {},
            "seen": dict(faults_seen),
            "contained": faults_contained,
            "retries": fault_retries,
            "failed": faults_failed,
            "kernel_faults": kernel_faults,
            "degraded_paged_attn": degraded,
            "quarantined_blocks": (pool.quarantined_blocks
                                   if self.paged else 0),
            "last_error": (repr(last_fault_error)
                           if last_fault_error is not None else None),
        }
        if grail is not None:
            counters["guardrail"] = {
                "every": grail.every,
                "margin": grail.margin,
                "checks": grail.checks,
                "breaches": grail.breaches,
                "last_mse": grail.last_mse,
                "restored_at": grail.restored_at,
                "swaps": list(guardrail_swaps),
            }
        if self.adaptive is not None:
            counters["adaptive"] = {
                "taus": list(self.adaptive.taus),
                "final_level": self.adaptive.level,
                "final_tau": self.adaptive.tau,
                "downshifts": self.adaptive.downshifts,
                "restores": self.adaptive.restores,
                "swaps": list(adaptive_swaps),
            }
        if stall_s:
            arr = np.sort(np.asarray(stall_s, np.float64))
            counters["decode_stall_p50_s"] = float(arr[len(arr) // 2])
            counters["decode_stall_p99_s"] = float(
                arr[min(len(arr) - 1, int(0.99 * len(arr)))])
        if self.paged:
            blk_bytes = paged_block_bytes(self.model, pool.block_size)
            # slot-major SSM state is allocated per slot up front in paged
            # mode too — include it so the dense comparison is symmetric
            slot_bytes = paged_slot_bytes(self.model, pool.block_size)
            counters.update(
                block_size=pool.block_size, n_blocks=pool.n_blocks,
                peak_blocks_in_use=peak_blocks,
                free_blocks_final=pool.n_free_blocks,
                kv_bytes_per_block=blk_bytes,
                peak_kv_bytes=(peak_blocks * blk_bytes
                               + self.n_slots * slot_bytes),
                # modeled per-drain attention K/V HBM reads across all
                # decode steps: what the active path read, plus both models
                # so one run exposes the fused-vs-gather ratio. Live tokens
                # summed per step (vs the provisioned per-step capacity)
                # give the occupancy these byte models scale with.
                paged_attn=self.paged_attn,
                decode_attn_bytes_read=(
                    attn_pages_fused if self.paged_attn == "fused"
                    else attn_pages_gather) * blk_bytes,
                decode_attn_bytes_fused_model=attn_pages_fused * blk_bytes,
                decode_attn_bytes_gather_model=attn_pages_gather * blk_bytes,
                decode_live_token_steps=live_token_steps,
                decode_capacity_token_steps=(n_steps * self.n_slots
                                             * pool.max_blocks
                                             * pool.block_size),
                # prefix cache economics: tokens whose prefill was skipped
                # because a resident block chain already held them
                prefix_cache=bool(self.prefix_cache),
                prefix_hit_requests=pool.prefix_hit_requests,
                prefix_hit_blocks=pool.prefix_hit_blocks,
                prefix_hit_tokens=pool.prefix_hit_tokens,
                cow_forks=pool.cow_forks,
                cached_blocks_final=pool.n_cached_blocks,
                reclaimed_cached_blocks=pool.reclaimed_cached_blocks)
        else:
            counters["peak_kv_bytes"] = counters["dense_kv_bytes"]
        # throughput over the decode phase only: each request's first token
        # comes out of its prefill, whose wall time is accounted as TTFT
        return ServeSummary(results=results, n_steps=n_steps,
                            decode_s=decode_s, total_s=total_s,
                            tokens_per_s=(n_decoded / decode_s
                                          if decode_s > 0 else 0.0),
                            counters=counters)
