"""Serving engines: one-shot batch serving and continuous batching.

TTFT (the paper's measured quantity, Sec. 2.3.1) = wall time of the compiled
prefill step. Both engines accept ``mp`` as an op->format dict *or* an
``MPPlan`` straight from ``core.pipeline.auto_mixed_precision``, so an
IP-solver artifact is directly servable.

* :class:`ServeEngine` — the paper-measurement harness: one batch in, greedy
  decode to completion, report TTFT + decode throughput.
* :class:`ContinuousBatchingEngine` — production-shaped serving: a request
  queue drains through a fixed pool of cache slots; requests are admitted
  *mid-decode* as slots free up (scheduler), each prefilled request's cache
  is scattered into its slot (cache pool), and one compiled decode step
  advances every occupied slot at its own sequence depth (per-slot position
  vectors). Greedy tokens are identical to the one-shot path — batching is
  across independent cache rows, never across a sequence's own math.

Continuous serving defaults to the **paged** KV layout (``paged=True``):
attention caches are block-major (``PagedCachePool``), admission is
block-budget-aware (a request only enters when its worst-case block need is
coverable — otherwise it queues, the backpressure path), and the compiled
decode step takes the per-slot block tables. ``paged=False`` keeps the dense
per-slot rings for comparison. Token parity with the dense/one-shot path is
exact either way: the paged gather reproduces the dense key layout in
logical order, and the causal mask hides everything else.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpconfig import as_assignment
from repro.launch.steps import (make_decode_step, make_paged_decode_step,
                                make_prefill_step)
from repro.serve.cache_pool import (CachePool, PagedCachePool,
                                    dense_slot_bytes, paged_block_bytes,
                                    paged_slot_bytes)
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "GenResult",
           "ServeSummary"]


@dataclasses.dataclass
class GenResult:
    tokens: jax.Array
    ttft_s: float
    decode_s: float
    tokens_per_s: float


@dataclasses.dataclass
class ServeSummary:
    """Outcome of draining a request queue through the continuous engine.

    ``counters`` carries the occupancy/backpressure signals a future
    SLA-aware re-solve hook needs (ROADMAP): peak queue depth, blocked
    admissions, peak live tokens, and — under paging — block occupancy and
    the KV HBM actually pinned (``peak_kv_bytes``) vs the dense-slot cost
    (``dense_kv_bytes``).
    """
    results: dict                     # rid -> RequestResult
    n_steps: int                      # decode steps executed
    decode_s: float                   # wall time inside decode steps
    total_s: float                    # wall time of the whole drain
    tokens_per_s: float               # decode-produced tokens / decode_s
    counters: dict = dataclasses.field(default_factory=dict)

    def tokens_for(self, rid: int) -> np.ndarray:
        return self.results[rid].tokens


class ServeEngine:
    """One-shot batch serving: prefill + lock-step greedy decode."""

    def __init__(self, model, mp=None, mesh=None, donate: bool = True):
        self.model = model
        self.mp = as_assignment(mp)
        self.mesh = mesh
        d = (1,) if donate else ()
        self.prefill_step = jax.jit(make_prefill_step(model, mp=self.mp),
                                    donate_argnums=d)
        self.decode_step = jax.jit(make_decode_step(model, mp=self.mp),
                                   donate_argnums=d)

    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0):
        # explicit capability check: enc-dec models declare that their cache
        # needs the encoder length (for pre-computed cross-attention K/V)
        # instead of the engine relying on call-arity coincidence
        if getattr(self.model, "cache_needs_enc_len", False):
            return self.model.init_cache(batch, max_len, enc_len)
        return self.model.init_cache(batch, max_len)

    def ttft(self, params, batch: dict, max_len: int, n_iters: int = 5,
             n_warmup: int = 2) -> float:
        """Median prefill wall time (the paper averages 5 iterations)."""
        B = batch["tokens"].shape[0]
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        times = []
        for i in range(n_warmup + n_iters):
            caches = self.init_caches(B, max_len, enc_len)
            t0 = time.perf_counter()
            logits, caches = self.prefill_step(params, caches, batch)
            jax.block_until_ready(logits)
            if i >= n_warmup:
                times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    # ------------------------------------------------------------------
    def generate(self, params, batch: dict, max_new_tokens: int,
                 max_len: Optional[int] = None) -> GenResult:
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        prefix = 0
        if batch.get("prefix_embeds") is not None:
            prefix = batch["prefix_embeds"].shape[1]
        max_len = max_len or (T0 + prefix + max_new_tokens)
        caches = self.init_caches(B, max_len, enc_len)

        t0 = time.perf_counter()
        logits, caches = self.prefill_step(params, caches, batch)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t0

        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        t1 = time.perf_counter()
        pos = T0 + prefix
        for i in range(max_new_tokens - 1):
            logits, caches = self.decode_step(
                params, caches, out[-1][:, None], jnp.array(pos + i, jnp.int32))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        jax.block_until_ready(out[-1])
        dt = time.perf_counter() - t1
        toks = jnp.stack(out, axis=1)
        return GenResult(tokens=toks, ttft_s=ttft, decode_s=dt,
                         tokens_per_s=B * max_new_tokens / max(dt, 1e-9))


class ContinuousBatchingEngine:
    """Continuous batching over a fixed pool of cache slots.

    The drain loop alternates two phases per clock tick:

    1. *admission* — while a slot is free and the FCFS queue head has
       arrived, prefill it (batch=1), scatter its cache into the slot, and
       record its first greedy token + TTFT;
    2. *decode* — one compiled step over all ``n_slots`` rows with per-slot
       ``(B,)`` position and token vectors; finished requests release their
       slot, which the next tick's admission phase can immediately reuse.

    Vacant slots decode garbage rows; their outputs are ignored and their
    cache rows (dense) are fully overwritten at the next insert — or their
    writes land in the paged pool's trash block — so they cost FLOPs but
    never correctness. Prefill compiles once per distinct prompt length in
    both layouts (the token operand's shape is per-length even though the
    paged prefill cache is block-rounded) — bucket prompts upstream if that
    matters.
    """

    def __init__(self, model, n_slots: int = 4, max_len: int = 512,
                 mp=None, donate: bool = False, paged: bool = True,
                 block_size: int = 16, n_blocks: Optional[int] = None):
        if getattr(model, "cache_needs_enc_len", False):
            raise NotImplementedError(
                "continuous batching currently serves decoder-only LMs")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.mp = as_assignment(mp)
        if not paged and n_blocks is not None:
            raise ValueError("n_blocks only applies to paged mode; drop it "
                             "or remove paged=False")
        self.paged = paged
        self.block_size = block_size
        self.n_blocks = n_blocks
        d = (1,) if donate else ()
        self.prefill_step = jax.jit(make_prefill_step(model, mp=self.mp))
        mk = make_paged_decode_step if paged else make_decode_step
        self.decode_step = jax.jit(mk(model, mp=self.mp), donate_argnums=d)

    # ------------------------------------------------------------------
    def _make_pool(self):
        if self.paged:
            return PagedCachePool(self.model, self.n_slots, self.max_len,
                                  block_size=self.block_size,
                                  n_blocks=self.n_blocks)
        return CachePool(self.model, self.n_slots, self.max_len)

    def _admit(self, params, pool, sched: Scheduler,
               results: dict, now: int) -> None:
        gate = None
        if self.paged:
            def gate(r):
                need = pool.blocks_for_request(r.prompt_len, r.max_new_tokens)
                if need > pool.n_blocks - 1:
                    # would block the queue forever — fail fast instead
                    raise ValueError(
                        f"request {r.rid} needs {need} KV blocks but the "
                        f"pool has only {pool.n_blocks - 1}; raise "
                        f"--n-blocks or shrink the request")
                return pool.can_admit(r.prompt_len, r.max_new_tokens)
        while pool.n_free_slots:
            st = sched.pop_admissible(now, gate)
            if st is None:
                return
            req = st.request
            assert req.prompt_len + req.max_new_tokens <= self.max_len, (
                f"request {req.rid}: {req.prompt_len}+{req.max_new_tokens} "
                f"exceeds pool max_len {self.max_len}")
            tokens = jnp.asarray(np.asarray(req.tokens, np.int32))[None]
            if self.paged:
                slot = pool.alloc_slot(req.prompt_len, req.max_new_tokens)
                # prefill into a dense batch=1 cache sized to the prompt's
                # block span, then scatter it into freshly allocated blocks;
                # ring_window=False keeps full-width K/V rows so the block
                # reshape is exact even when the prompt exceeds a sliding
                # window (the window is enforced by the mask either way)
                plen = pool.blocks_for(req.prompt_len) * pool.block_size
                cache1 = self.model.init_cache(1, plen, ring_window=False)
            else:
                slot = pool.alloc()
                cache1 = self.model.init_cache(1, self.max_len)
            t0 = time.perf_counter()
            logits, cache1 = self.prefill_step(params, cache1,
                                               {"tokens": tokens})
            jax.block_until_ready(logits)
            ttft = time.perf_counter() - t0
            if self.paged:
                pool.insert(slot, cache1, req.prompt_len)
            else:
                pool.insert(slot, cache1)
            first = int(jnp.argmax(logits[0, -1]))
            sched.start(st, slot, first, ttft, now)
            if st.done:                      # max_new_tokens == 1
                results[req.rid] = sched.finish(st, now)
                pool.free_slot(slot)

    def serve(self, params, requests: Sequence[Request]) -> ServeSummary:
        """Drain ``requests`` (any arrival order) and return all results."""
        pool = self._make_pool()
        sched = Scheduler()
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            sched.submit(r)

        results: dict = {}
        tok_host = np.zeros((self.n_slots, 1), np.int32)
        pos_host = np.zeros((self.n_slots,), np.int32)
        now = 0
        n_steps = 0
        decode_s = 0.0
        peak_queue = peak_live = peak_blocks = peak_slots = 0
        t_start = time.perf_counter()
        while sched.has_work():
            self._admit(params, pool, sched, results, now)
            peak_queue = max(peak_queue, sched.queue_depth)
            if sched.running:
                tok_host[:] = 0
                pos_host[:] = 0
                for slot, st in sched.running.items():
                    tok_host[slot, 0] = st.last_token
                    pos_host[slot] = st.next_pos
                    if self.paged:
                        pool.ensure_block(slot, st.next_pos)
                # live tokens after this step: everything written so far
                # (next_pos) plus the write this step performs
                peak_live = max(peak_live, sum(
                    st.next_pos + 1 for st in sched.running.values()))
                peak_slots = max(peak_slots, len(sched.running))
                if self.paged:
                    peak_blocks = max(peak_blocks, pool.blocks_in_use)
                t0 = time.perf_counter()
                if self.paged:
                    logits, pool.caches = self.decode_step(
                        params, pool.caches, jnp.asarray(tok_host),
                        jnp.asarray(pos_host), pool.block_tables_device())
                else:
                    logits, pool.caches = self.decode_step(
                        params, pool.caches, jnp.asarray(tok_host),
                        jnp.asarray(pos_host))
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
                decode_s += time.perf_counter() - t0
                n_steps += 1
                for slot in list(sched.running):
                    st = sched.record_token(slot, int(nxt[slot]))
                    if st.done:
                        results[st.request.rid] = sched.finish(st, now)
                        pool.free_slot(slot)
                now += 1
            else:
                # idle: jump the clock to the next arrival instead of spinning
                nxt_arrival = sched.next_arrival()
                if nxt_arrival is None:
                    break
                now = max(now + 1, nxt_arrival)

        total_s = time.perf_counter() - t_start
        counters = {
            "paged": self.paged,
            "peak_queue_depth": peak_queue,
            "blocked_admissions": sched.blocked_admissions,
            "peak_live_tokens": peak_live,
            "peak_slots_in_use": peak_slots,
            "dense_kv_bytes": self.n_slots * dense_slot_bytes(self.model,
                                                              self.max_len),
        }
        if self.paged:
            blk_bytes = paged_block_bytes(self.model, pool.block_size)
            # slot-major SSM state is allocated per slot up front in paged
            # mode too — include it so the dense comparison is symmetric
            slot_bytes = paged_slot_bytes(self.model, pool.block_size)
            counters.update(
                block_size=pool.block_size, n_blocks=pool.n_blocks,
                peak_blocks_in_use=peak_blocks,
                free_blocks_final=pool.n_free_blocks,
                kv_bytes_per_block=blk_bytes,
                peak_kv_bytes=(peak_blocks * blk_bytes
                               + self.n_slots * slot_bytes))
        else:
            counters["peak_kv_bytes"] = counters["dense_kv_bytes"]
        # throughput over the decode phase only: each request's first token
        # comes out of its prefill, whose wall time is accounted as TTFT
        n_decoded = sum(max(len(r.tokens) - 1, 0) for r in results.values())
        return ServeSummary(results=results, n_steps=n_steps,
                            decode_s=decode_s, total_s=total_s,
                            tokens_per_s=(n_decoded / decode_s
                                          if decode_s > 0 else 0.0),
                            counters=counters)
