"""Serving engine: batched prefill + decode under an MP assignment.

TTFT (the paper's measured quantity) = wall time of the compiled prefill
step. ``generate`` runs greedy decode over the KV/SSM caches. The engine
accepts an op->format assignment produced by the AMP pipeline and builds the
quantized step functions from it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.encdec import EncDec

__all__ = ["ServeEngine"]


@dataclasses.dataclass
class GenResult:
    tokens: jax.Array
    ttft_s: float
    decode_s: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, model, mp: Optional[dict] = None, mesh=None,
                 donate: bool = True):
        self.model = model
        self.mp = mp or {}
        self.mesh = mesh
        d = (1,) if donate else ()
        self.prefill_step = jax.jit(make_prefill_step(model, mp=self.mp),
                                    donate_argnums=d)
        self.decode_step = jax.jit(make_decode_step(model, mp=self.mp),
                                   donate_argnums=d)

    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0):
        if isinstance(self.model, EncDec):
            return self.model.init_cache(batch, max_len, enc_len)
        return self.model.init_cache(batch, max_len)

    def ttft(self, batch: dict, max_len: int, n_iters: int = 5,
             n_warmup: int = 2) -> float:
        """Median prefill wall time (the paper averages 5 iterations)."""
        B = batch["tokens"].shape[0]
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        times = []
        for i in range(n_warmup + n_iters):
            caches = self.init_caches(B, max_len, enc_len)
            t0 = time.perf_counter()
            logits, caches = self.prefill_step(self.model_params, caches, batch)
            jax.block_until_ready(logits)
            if i >= n_warmup:
                times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    # ------------------------------------------------------------------
    def generate(self, params, batch: dict, max_new_tokens: int,
                 max_len: Optional[int] = None) -> GenResult:
        self.model_params = params
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        prefix = 0
        if batch.get("prefix_embeds") is not None:
            prefix = batch["prefix_embeds"].shape[1]
        max_len = max_len or (T0 + prefix + max_new_tokens)
        caches = self.init_caches(B, max_len, enc_len)

        t0 = time.perf_counter()
        logits, caches = self.prefill_step(params, caches, batch)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t0

        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        t1 = time.perf_counter()
        pos = T0 + prefix
        for i in range(max_new_tokens - 1):
            logits, caches = self.decode_step(
                params, caches, out[-1][:, None], jnp.array(pos + i, jnp.int32))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        jax.block_until_ready(out[-1])
        dt = time.perf_counter() - t1
        toks = jnp.stack(out, axis=1)
        return GenResult(tokens=toks, ttft_s=ttft, decode_s=dt,
                         tokens_per_s=B * max_new_tokens / max(dt, 1e-9))
