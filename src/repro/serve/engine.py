"""Serving engines: one-shot batch serving and continuous batching.

TTFT (the paper's measured quantity, Sec. 2.3.1) = wall time of the compiled
prefill step. Both engines accept ``mp`` as an op->format dict *or* an
``MPPlan`` straight from ``core.pipeline.auto_mixed_precision``, so an
IP-solver artifact is directly servable.

* :class:`ServeEngine` — the paper-measurement harness: one batch in, greedy
  decode to completion, report TTFT + decode throughput.
* :class:`ContinuousBatchingEngine` — production-shaped serving: a request
  queue drains through a fixed pool of cache slots; requests are admitted
  *mid-decode* as slots free up (scheduler), each prefilled request's cache
  is scattered into its slot (cache pool), and one compiled decode step
  advances every occupied slot at its own sequence depth (per-slot position
  vectors). Greedy tokens are identical to the one-shot path — batching is
  across independent cache rows, never across a sequence's own math.

Continuous serving defaults to the **paged** KV layout (``paged=True``):
attention caches are block-major (``PagedCachePool``), admission is
block-budget-aware (a request only enters when its worst-case block need is
coverable — otherwise it queues, the backpressure path), and the compiled
decode step takes the per-slot block tables. ``paged=False`` keeps the dense
per-slot rings for comparison. Paged decode attention defaults to the
**fused** Pallas kernel (``paged_attn="fused"``): block-table indirection is
resolved in-kernel and each step reads only live KV blocks (fp8 caches
dequantized in-register), instead of the ``paged_attn="gather"`` reference
path that materializes the full ``(B, max_blocks * block_size)`` K/V per
layer. Token parity with the dense/one-shot path is exact either way: the
fused kernel reproduces the reference softmax numerics (two-phase, final
max/denominator), the paged gather reproduces the dense key layout in
logical order, and the causal mask / length masking hides everything else.

Prefill is **length-bucketed** in both engines: prompts are padded to a
power-of-two bucket with masked attention/state updates, so admission
compiles O(#buckets) programs instead of O(#distinct prompt lengths). In
paged mode it is additionally **chunked** (``chunk_len``): a prompt longer
than the chunk budget is split into fixed-size chunks written straight into
the slot's paged blocks ("paged prefill" — no dense-then-scatter), each
chunk interleaved with decode steps under a TTFT-aware arbitration budget
(``chunk_budget`` chunk steps per decode step at most), so a long prompt
consumes bounded per-step latency and never head-of-line-blocks decoding
slots. Greedy tokens stay bit-identical to the one-shot engine for prompts
whose bucket stays below ``flash_min_seq``: the serving quant policy uses
per-token activation scales and prefill attends through the KV-cache
storage dtype, making the math invariant to batching, padding and chunk
splits. (At or past ``flash_min_seq`` the one-shot engine takes the
blocked flash kernel, whose summation order differs from the reference
path the chunked step always uses — see the serve README.)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpconfig import as_assignment
from repro.launch.steps import (make_bucketed_prefill_step,
                                make_chunked_prefill_step, make_decode_step,
                                make_paged_decode_step, make_prefill_step)
from repro.serve.cache_pool import (CachePool, PagedCachePool,
                                    dense_slot_bytes, paged_block_bytes,
                                    paged_slot_bytes)
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "GenResult",
           "ServeSummary", "prefill_bucket"]


def prefill_bucket(n: int, chunk_len: Optional[int] = None,
                   min_bucket: int = 8) -> int:
    """Padded length for a prefill chunk of ``n`` real tokens: the next
    power of two (>= ``min_bucket``), clamped to ``chunk_len`` when chunking
    is on. Admission compiles one prefill program per bucket instead of one
    per distinct prompt length."""
    assert n >= 1, n
    b = max(min_bucket, 1 << (n - 1).bit_length())
    if chunk_len is not None:
        assert n <= chunk_len, (n, chunk_len)
        b = min(b, chunk_len)
    return b


@dataclasses.dataclass
class GenResult:
    tokens: jax.Array
    ttft_s: float
    decode_s: float
    tokens_per_s: float


@dataclasses.dataclass
class ServeSummary:
    """Outcome of draining a request queue through the continuous engine.

    ``counters`` carries the occupancy/backpressure signals a future
    SLA-aware re-solve hook needs (ROADMAP): peak queue depth, blocked
    admissions, peak live tokens, and — under paging — block occupancy and
    the KV HBM actually pinned (``peak_kv_bytes``) vs the dense-slot cost
    (``dense_kv_bytes``).
    """
    results: dict                     # rid -> RequestResult
    n_steps: int                      # decode steps executed
    decode_s: float                   # wall time inside decode steps
    total_s: float                    # wall time of the whole drain
    tokens_per_s: float               # decode-produced tokens / decode_s
    counters: dict = dataclasses.field(default_factory=dict)

    def tokens_for(self, rid: int) -> np.ndarray:
        return self.results[rid].tokens


class ServeEngine:
    """One-shot batch serving: prefill + lock-step greedy decode.

    Prefill is length-bucketed for decoder-only LMs on plain token prompts:
    the prompt is padded to a power-of-two bucket and masked, so the compile
    cache is keyed by bucket (the same bucketed step the continuous engine
    uses in dense mode) instead of by distinct prompt length. Multimodal
    prefixes and encoder-decoder models keep the legacy per-length step.
    """

    def __init__(self, model, mp=None, mesh=None, donate: bool = True):
        self.model = model
        self.mp = as_assignment(mp)
        self.mesh = mesh
        d = (1,) if donate else ()
        self.prefill_step = jax.jit(make_prefill_step(model, mp=self.mp),
                                    donate_argnums=d)
        self.decode_step = jax.jit(make_decode_step(model, mp=self.mp),
                                   donate_argnums=d)
        self._bucketed = getattr(model, "supports_prefill_chunk", False)
        if self._bucketed:
            self.bucketed_prefill_step = jax.jit(
                make_bucketed_prefill_step(model, mp=self.mp),
                donate_argnums=d)
        # compile-economy bookkeeping: which prefill programs this engine
        # needed vs how many distinct prompt lengths it served
        self.prefill_compile_keys: set = set()
        self.prompt_lens_seen: set = set()

    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0):
        # explicit capability check: enc-dec models declare that their cache
        # needs the encoder length (for pre-computed cross-attention K/V)
        # instead of the engine relying on call-arity coincidence
        if getattr(self.model, "cache_needs_enc_len", False):
            return self.model.init_cache(batch, max_len, enc_len)
        return self.model.init_cache(batch, max_len)

    def _prefill(self, params, caches, batch: dict):
        """Dispatch prefill: bucketed (compiled per power-of-two bucket) when
        the model supports it and the batch is plain tokens; the legacy
        per-length step otherwise. Returns (last-token logits, caches)."""
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        self.prompt_lens_seen.add(int(T0))
        Lb = prefill_bucket(T0)
        # legacy per-length step for multimodal/enc-dec batches, and for
        # prompts whose *bucket* reaches flash_min_seq: the bucketed step
        # never flashes (padding must not change the summation order), so
        # long prompts keep the flash-capable pre-bucketing path — and its
        # exact pre-bucketing numerics — at per-length compile cost
        if (not self._bucketed or "frames" in batch
                or batch.get("prefix_embeds") is not None
                or Lb >= getattr(self.model.cfg, "flash_min_seq", 1 << 30)):
            self.prefill_compile_keys.add(("legacy", int(T0)))
            return self.prefill_step(params, caches, batch)
        self.prefill_compile_keys.add(Lb)
        tok = jnp.pad(jnp.asarray(tokens, jnp.int32),
                      ((0, 0), (0, Lb - T0)))
        start = jnp.zeros((B,), jnp.int32)
        valid = jnp.full((B,), T0, jnp.int32)
        return self.bucketed_prefill_step(params, caches, tok, start, valid)

    def ttft(self, params, batch: dict, max_len: int, n_iters: int = 5,
             n_warmup: int = 2) -> float:
        """Median prefill wall time (the paper averages 5 iterations).

        Measures the *serving* prefill path: short prompts run the bucketed
        step, so the cost includes pow-2 bucket padding (that is what a
        deployment executes); prompts at or beyond flash_min_seq run the
        legacy unpadded flash-capable step, keeping long-context TTFT
        comparable with pre-bucketing measurements."""
        B = batch["tokens"].shape[0]
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        times = []
        for i in range(n_warmup + n_iters):
            caches = self.init_caches(B, max_len, enc_len)
            t0 = time.perf_counter()
            logits, caches = self._prefill(params, caches, batch)
            jax.block_until_ready(logits)
            if i >= n_warmup:
                times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    # ------------------------------------------------------------------
    def generate(self, params, batch: dict, max_new_tokens: int,
                 max_len: Optional[int] = None) -> GenResult:
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        prefix = 0
        if batch.get("prefix_embeds") is not None:
            prefix = batch["prefix_embeds"].shape[1]
        max_len = max_len or (T0 + prefix + max_new_tokens)
        caches = self.init_caches(B, max_len, enc_len)

        t0 = time.perf_counter()
        logits, caches = self._prefill(params, caches, batch)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t0

        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        t1 = time.perf_counter()
        pos = T0 + prefix
        for i in range(max_new_tokens - 1):
            logits, caches = self.decode_step(
                params, caches, out[-1][:, None], jnp.array(pos + i, jnp.int32))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        jax.block_until_ready(out[-1])
        dt = time.perf_counter() - t1
        toks = jnp.stack(out, axis=1)
        return GenResult(tokens=toks, ttft_s=ttft, decode_s=dt,
                         tokens_per_s=B * max_new_tokens / max(dt, 1e-9))


class ContinuousBatchingEngine:
    """Continuous batching over a fixed pool of cache slots.

    The drain loop alternates two phases per clock tick:

    1. *admission* — while a slot is free and the FCFS queue head has
       arrived, prefill it (batch=1), scatter its cache into the slot, and
       record its first greedy token + TTFT;
    2. *decode* — one compiled step over all ``n_slots`` rows with per-slot
       ``(B,)`` position and token vectors; finished requests release their
       slot, which the next tick's admission phase can immediately reuse.

    Vacant slots decode garbage rows; their outputs are ignored and their
    cache rows (dense) are fully reset at the next first-chunk prefill — or
    their writes land in the paged pool's trash block — so they cost FLOPs
    but never correctness.

    Prefill runs *in place* on the pool's caches with the decode batch
    width: each prefill-chunk step carries (tokens, start, valid) vectors
    over all ``n_slots`` rows, co-batching every prefilling slot whose next
    chunk shares a bucket while decoding/vacant rows pass through untouched
    (valid = 0). Paged mode writes the chunk straight into the slot's
    physical blocks (allocated incrementally per chunk); dense mode buckets
    whole prompts into the slot's ring. Compile cost is O(#buckets).

    ``chunk_len`` (paged only) splits prompts longer than the budget into
    fixed-size chunks; the step loop then interleaves at most
    ``chunk_budget`` chunk steps per decode step, so no decoding slot ever
    waits more than ``chunk_budget`` steps while a long prompt prefills
    (``ServeSummary.counters``: ``prefill_chunks``, ``decode_stall_steps``,
    ``max_decode_stall_run``, stall percentiles).

    ``paged_attn`` (paged only) selects the decode-attention implementation:
    ``"fused"`` (default) runs the Pallas paged-attention kernel directly
    over the block-major cache; ``"gather"`` keeps the reference
    gather-then-attend path. Greedy tokens are identical; the counters
    ``decode_attn_bytes_{read,fused_model,gather_model}`` expose the
    live-vs-capacity HBM-read gap between the two.
    """

    def __init__(self, model, n_slots: int = 4, max_len: int = 512,
                 mp=None, donate: bool = False, paged: bool = True,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 chunk_len: Optional[int] = None, chunk_budget: int = 1,
                 min_bucket: int = 8, paged_attn: Optional[str] = None):
        if getattr(model, "cache_needs_enc_len", False):
            raise NotImplementedError(
                "continuous batching currently serves decoder-only LMs")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.mp = as_assignment(mp)
        if not paged and n_blocks is not None:
            raise ValueError("n_blocks only applies to paged mode; drop it "
                             "or remove paged=False")
        if paged_attn is not None and not paged:
            raise ValueError("paged_attn selects the paged decode-attention "
                             "implementation; drop it or remove paged=False")
        if paged_attn is None:
            paged_attn = "fused"
        if paged_attn not in ("fused", "gather"):
            raise ValueError(f"paged_attn must be 'fused' or 'gather', got "
                             f"{paged_attn!r}")
        self.paged_attn = paged_attn
        if chunk_len is not None:
            if not paged:
                raise ValueError(
                    "chunked prefill writes paged KV blocks; dense mode "
                    "buckets whole prompts (drop chunk_len or use "
                    "paged=True)")
            assert chunk_len >= 1, chunk_len
            ssm = getattr(model.cfg, "ssm", None)
            if ssm is not None and chunk_len % ssm.chunk != 0:
                raise ValueError(
                    f"chunk_len {chunk_len} must be a multiple of the SSD "
                    f"chunk ({ssm.chunk}): engine chunk boundaries must "
                    f"align with the SSD state recurrence for bit-exact "
                    f"resume (override cfg.ssm.chunk or pick another "
                    f"chunk_len)")
        assert chunk_budget >= 1, chunk_budget
        self.paged = paged
        self.block_size = block_size
        self.n_blocks = n_blocks
        self.chunk_len = chunk_len
        self.chunk_budget = chunk_budget
        self.min_bucket = min_bucket
        d = (1,) if donate else ()
        mk_prefill = (make_chunked_prefill_step if paged
                      else make_bucketed_prefill_step)
        self.prefill_chunk_step = jax.jit(mk_prefill(model, mp=self.mp))
        if paged:
            step = make_paged_decode_step(model, mp=self.mp,
                                          paged_attn=paged_attn)
        else:
            step = make_decode_step(model, mp=self.mp)
        self.decode_step = jax.jit(step, donate_argnums=d)
        # compile-economy bookkeeping (persists across serve() calls, like
        # the jit compile cache it mirrors)
        self.prefill_compile_keys: set = set()
        self.prompt_lens_seen: set = set()
        self._warned_flash = False

    # ------------------------------------------------------------------
    def _make_pool(self):
        if self.paged:
            return PagedCachePool(self.model, self.n_slots, self.max_len,
                                  block_size=self.block_size,
                                  n_blocks=self.n_blocks)
        return CachePool(self.model, self.n_slots, self.max_len)

    def _admit(self, params, pool, sched: Scheduler,
               results: dict, now: int) -> None:
        """Claim slots for admissible requests and emit prefill work items;
        no device work happens here — the step loop drives the chunks."""
        gate = None
        if self.paged:
            def gate(r):
                need = pool.blocks_for_request(r.prompt_len, r.max_new_tokens)
                if need > pool.n_blocks - 1:
                    # would block the queue forever — fail fast instead
                    raise ValueError(
                        f"request {r.rid} needs {need} KV blocks but the "
                        f"pool has only {pool.n_blocks - 1}; raise "
                        f"--n-blocks or shrink the request")
                return pool.can_admit(r.prompt_len, r.max_new_tokens)
        while pool.n_free_slots:
            st = sched.pop_admissible(now, gate)
            if st is None:
                return
            req = st.request
            assert req.prompt_len + req.max_new_tokens <= self.max_len, (
                f"request {req.rid}: {req.prompt_len}+{req.max_new_tokens} "
                f"exceeds pool max_len {self.max_len}")
            self.prompt_lens_seen.add(req.prompt_len)
            # documented parity boundary, enforced with a one-time warning:
            # the chunked/bucketed step never flashes, so once a chunk
            # bucket reaches flash_min_seq, greedy tokens may differ from a
            # flash-capable one-shot reference in low-order summation bits
            flash_min = getattr(self.model.cfg, "flash_min_seq", 1 << 30)
            biggest = min(req.prompt_len, self.chunk_len or req.prompt_len)
            if (not self._warned_flash
                    and prefill_bucket(biggest, self.chunk_len,
                                       self.min_bucket) >= flash_min):
                self._warned_flash = True
                print(f"[serve] warning: prefill bucket >= flash_min_seq "
                      f"({flash_min}); chunked prefill uses the reference "
                      f"attention path, so bit-parity with a flash one-shot "
                      f"reference is not guaranteed at these lengths")
            if self.paged:
                # reservation only — blocks materialize chunk by chunk
                slot = pool.alloc_slot(req.prompt_len, req.max_new_tokens)
            else:
                slot = pool.alloc()
            sched.start_prefill(st, slot, now)
            st.wall_admitted = time.perf_counter()

    def _prefill_tick(self, params, pool, sched: Scheduler,
                      results: dict, now: int) -> float:
        """Run one compiled prefill-chunk step: co-batch the next chunk of
        every prefilling slot whose bucket matches the FCFS head's, padded
        to the bucket, over the full ``n_slots`` batch (inactive rows pass
        through with valid = 0). Returns the step's wall time."""
        items = []
        bucket = None
        for slot, st in sched.prefilling.items():
            start = st.prefill_pos
            take = st.request.prompt_len - start
            if self.chunk_len is not None:
                take = min(take, self.chunk_len)
            b = prefill_bucket(take, self.chunk_len, self.min_bucket)
            if bucket is None:
                bucket = b
            if b == bucket:
                items.append((slot, st, start, take))
        self.prefill_compile_keys.add(bucket)
        tok = np.zeros((self.n_slots, bucket), np.int32)
        start_v = np.ones((self.n_slots,), np.int32)   # >0: leave row alone
        valid_v = np.zeros((self.n_slots,), np.int32)  # 0: inactive row
        for slot, st, start, take in items:
            tok[slot, :take] = np.asarray(st.request.tokens,
                                          np.int32)[start:start + take]
            start_v[slot] = start
            valid_v[slot] = take
            if self.paged:
                pool.ensure_range(slot, start, start + take)
        t0 = time.perf_counter()
        if self.paged:
            logits, pool.caches = self.prefill_chunk_step(
                params, pool.caches, jnp.asarray(tok), jnp.asarray(start_v),
                jnp.asarray(valid_v), pool.block_tables_device())
        else:
            logits, pool.caches = self.prefill_chunk_step(
                params, pool.caches, jnp.asarray(tok), jnp.asarray(start_v),
                jnp.asarray(valid_v))
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        dt = time.perf_counter() - t0
        for slot, st, start, take in items:
            st = sched.prefill_advance(slot, take, dt)
            if st.prefill_pos == st.request.prompt_len:
                st = sched.finish_prefill(slot, int(nxt[slot]), now)
                # honest TTFT: wall time since admission, which includes the
                # decode steps interleaved between this request's chunks
                st.ttft_s = time.perf_counter() - st.wall_admitted
                if st.done:                  # max_new_tokens == 1
                    results[st.request.rid] = sched.finish(st, now)
                    pool.free_slot(slot)
        return dt

    def serve(self, params, requests: Sequence[Request]) -> ServeSummary:
        """Drain ``requests`` (any arrival order) and return all results."""
        pool = self._make_pool()
        sched = Scheduler()
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            sched.submit(r)

        results: dict = {}
        tok_host = np.zeros((self.n_slots, 1), np.int32)
        pos_host = np.zeros((self.n_slots,), np.int32)
        now = 0
        n_steps = 0
        decode_s = 0.0
        peak_queue = peak_live = peak_blocks = peak_slots = 0
        # per-decode-step attention HBM read model (paged): the fused kernel
        # fetches each running row's live pages (plus at most one trash-block
        # fetch per row whose tail pages are dead — consecutive dead pages
        # revisit block 0 and their copies are elided); the gather path
        # materializes every table slot of every row, so its traffic scales
        # with provisioned capacity
        attn_pages_fused = attn_pages_gather = live_token_steps = 0
        prefill_chunks = decode_stall_steps = max_stall_run = stall_run = 0
        stall_s_run = 0.0
        stall_s: list = []            # per-decode-step injected prefill time
        t_start = time.perf_counter()
        while sched.has_work():
            self._admit(params, pool, sched, results, now)
            peak_queue = max(peak_queue, sched.queue_depth)
            # prefill phase — TTFT-aware arbitration: prefill freely while
            # nothing is decoding, else at most chunk_budget chunk steps per
            # decode step so no decode slot stalls unboundedly
            chunks_this_tick = 0
            while sched.prefilling and (not sched.running
                                        or chunks_this_tick
                                        < self.chunk_budget):
                was_decoding = bool(sched.running)
                dt = self._prefill_tick(params, pool, sched, results, now)
                prefill_chunks += 1
                chunks_this_tick += 1
                if was_decoding:
                    decode_stall_steps += 1
                    stall_run += 1
                    max_stall_run = max(max_stall_run, stall_run)
                    stall_s_run += dt
                # a finished 1-token request frees its slot immediately:
                # let a queued request claim it this same tick
                self._admit(params, pool, sched, results, now)
            if sched.running:
                tok_host[:] = 0
                pos_host[:] = 0
                for slot, st in sched.running.items():
                    tok_host[slot, 0] = st.last_token
                    pos_host[slot] = st.next_pos
                    if self.paged:
                        pool.ensure_block(slot, st.next_pos)
                # live tokens after this step: everything written so far
                # (next_pos) plus the write this step performs
                live_now = sum(st.next_pos + 1
                               for st in sched.running.values())
                peak_live = max(peak_live, live_now)
                peak_slots = max(peak_slots, len(sched.running))
                if self.paged:
                    peak_blocks = max(peak_blocks, pool.blocks_in_use)
                    live_token_steps += live_now
                    pages = {s: -(-(st.next_pos + 1) // pool.block_size)
                             for s, st in sched.running.items()}
                    attn_pages_fused += sum(pages.values()) + sum(
                        1 for s in range(self.n_slots)
                        if pages.get(s, 0) < pool.max_blocks)
                    attn_pages_gather += self.n_slots * pool.max_blocks
                t0 = time.perf_counter()
                if self.paged:
                    # decode sees block tables only for *running* rows: a
                    # slot mid-prefill owns real blocks, and the vacant-row
                    # garbage write must go to the trash block, not into
                    # K/V its earlier chunks already wrote
                    bt = pool.block_tables.copy()
                    for s in range(self.n_slots):
                        if s not in sched.running:
                            bt[s] = -1
                    logits, pool.caches = self.decode_step(
                        params, pool.caches, jnp.asarray(tok_host),
                        jnp.asarray(pos_host), jnp.asarray(bt))
                else:
                    logits, pool.caches = self.decode_step(
                        params, pool.caches, jnp.asarray(tok_host),
                        jnp.asarray(pos_host))
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
                decode_s += time.perf_counter() - t0
                n_steps += 1
                stall_s.append(stall_s_run)
                stall_s_run = 0.0
                stall_run = 0
                for slot in list(sched.running):
                    st = sched.record_token(slot, int(nxt[slot]))
                    if st.done:
                        results[st.request.rid] = sched.finish(st, now)
                        pool.free_slot(slot)
                now += 1
            elif not sched.prefilling:
                # idle: jump the clock to the next arrival instead of spinning
                nxt_arrival = sched.next_arrival()
                if nxt_arrival is None:
                    break
                now = max(now + 1, nxt_arrival)

        total_s = time.perf_counter() - t_start
        counters = {
            "paged": self.paged,
            "peak_queue_depth": peak_queue,
            "blocked_admissions": sched.blocked_admissions,
            "peak_live_tokens": peak_live,
            "peak_slots_in_use": peak_slots,
            "dense_kv_bytes": self.n_slots * dense_slot_bytes(self.model,
                                                              self.max_len),
            # chunked/bucketed prefill economics + decode-stall signals
            "prefill_chunks": prefill_chunks,
            "decode_stall_steps": decode_stall_steps,
            "max_decode_stall_run": max_stall_run,
            "prefill_buckets": len(self.prefill_compile_keys),
            "distinct_prompt_lens": len(self.prompt_lens_seen),
        }
        if stall_s:
            arr = np.sort(np.asarray(stall_s, np.float64))
            counters["decode_stall_p50_s"] = float(arr[len(arr) // 2])
            counters["decode_stall_p99_s"] = float(
                arr[min(len(arr) - 1, int(0.99 * len(arr)))])
        if self.paged:
            blk_bytes = paged_block_bytes(self.model, pool.block_size)
            # slot-major SSM state is allocated per slot up front in paged
            # mode too — include it so the dense comparison is symmetric
            slot_bytes = paged_slot_bytes(self.model, pool.block_size)
            counters.update(
                block_size=pool.block_size, n_blocks=pool.n_blocks,
                peak_blocks_in_use=peak_blocks,
                free_blocks_final=pool.n_free_blocks,
                kv_bytes_per_block=blk_bytes,
                peak_kv_bytes=(peak_blocks * blk_bytes
                               + self.n_slots * slot_bytes),
                # modeled per-drain attention K/V HBM reads across all
                # decode steps: what the active path read, plus both models
                # so one run exposes the fused-vs-gather ratio. Live tokens
                # summed per step (vs the provisioned per-step capacity)
                # give the occupancy these byte models scale with.
                paged_attn=self.paged_attn,
                decode_attn_bytes_read=(
                    attn_pages_fused if self.paged_attn == "fused"
                    else attn_pages_gather) * blk_bytes,
                decode_attn_bytes_fused_model=attn_pages_fused * blk_bytes,
                decode_attn_bytes_gather_model=attn_pages_gather * blk_bytes,
                decode_live_token_steps=live_token_steps,
                decode_capacity_token_steps=(n_steps * self.n_slots
                                             * pool.max_blocks
                                             * pool.block_size))
        else:
            counters["peak_kv_bytes"] = counters["dense_kv_bytes"]
        # throughput over the decode phase only: each request's first token
        # comes out of its prefill, whose wall time is accounted as TTFT
        n_decoded = sum(max(len(r.tokens) - 1, 0) for r in results.values())
        return ServeSummary(results=results, n_steps=n_steps,
                            decode_s=decode_s, total_s=total_s,
                            tokens_per_s=(n_decoded / decode_s
                                          if decode_s > 0 else 0.0),
                            counters=counters)
