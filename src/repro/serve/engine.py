"""Serving engines: one-shot batch serving and continuous batching.

TTFT (the paper's measured quantity, Sec. 2.3.1) = wall time of the compiled
prefill step. Both engines accept ``mp`` as an op->format dict *or* an
``MPPlan`` straight from ``core.pipeline.auto_mixed_precision``, so an
IP-solver artifact is directly servable.

* :class:`ServeEngine` — the paper-measurement harness: one batch in, greedy
  decode to completion, report TTFT + decode throughput.
* :class:`ContinuousBatchingEngine` — production-shaped serving: a request
  queue drains through a fixed pool of cache slots; requests are admitted
  *mid-decode* as slots free up (scheduler), each prefilled request's cache
  is scattered into its slot (cache pool), and one compiled decode step
  advances every occupied slot at its own sequence depth (per-slot position
  vectors). Greedy tokens are identical to the one-shot path — batching is
  across independent cache rows, never across a sequence's own math.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpconfig import as_assignment
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models.encdec import EncDec
from repro.serve.cache_pool import CachePool
from repro.serve.scheduler import Request, Scheduler

__all__ = ["ServeEngine", "ContinuousBatchingEngine", "GenResult",
           "ServeSummary"]


@dataclasses.dataclass
class GenResult:
    tokens: jax.Array
    ttft_s: float
    decode_s: float
    tokens_per_s: float


@dataclasses.dataclass
class ServeSummary:
    """Outcome of draining a request queue through the continuous engine."""
    results: dict                     # rid -> RequestResult
    n_steps: int                      # decode steps executed
    decode_s: float                   # wall time inside decode steps
    total_s: float                    # wall time of the whole drain
    tokens_per_s: float               # decode-produced tokens / decode_s

    def tokens_for(self, rid: int) -> np.ndarray:
        return self.results[rid].tokens


class ServeEngine:
    """One-shot batch serving: prefill + lock-step greedy decode."""

    def __init__(self, model, mp=None, mesh=None, donate: bool = True):
        self.model = model
        self.mp = as_assignment(mp)
        self.mesh = mesh
        d = (1,) if donate else ()
        self.prefill_step = jax.jit(make_prefill_step(model, mp=self.mp),
                                    donate_argnums=d)
        self.decode_step = jax.jit(make_decode_step(model, mp=self.mp),
                                   donate_argnums=d)

    # ------------------------------------------------------------------
    def init_caches(self, batch: int, max_len: int, enc_len: int = 0):
        if isinstance(self.model, EncDec):
            return self.model.init_cache(batch, max_len, enc_len)
        return self.model.init_cache(batch, max_len)

    def ttft(self, params, batch: dict, max_len: int, n_iters: int = 5,
             n_warmup: int = 2) -> float:
        """Median prefill wall time (the paper averages 5 iterations)."""
        B = batch["tokens"].shape[0]
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        times = []
        for i in range(n_warmup + n_iters):
            caches = self.init_caches(B, max_len, enc_len)
            t0 = time.perf_counter()
            logits, caches = self.prefill_step(params, caches, batch)
            jax.block_until_ready(logits)
            if i >= n_warmup:
                times.append(time.perf_counter() - t0)
        times.sort()
        return times[len(times) // 2]

    # ------------------------------------------------------------------
    def generate(self, params, batch: dict, max_new_tokens: int,
                 max_len: Optional[int] = None) -> GenResult:
        tokens = batch["tokens"]
        B, T0 = tokens.shape
        enc_len = batch["frames"].shape[1] if "frames" in batch else 0
        prefix = 0
        if batch.get("prefix_embeds") is not None:
            prefix = batch["prefix_embeds"].shape[1]
        max_len = max_len or (T0 + prefix + max_new_tokens)
        caches = self.init_caches(B, max_len, enc_len)

        t0 = time.perf_counter()
        logits, caches = self.prefill_step(params, caches, batch)
        jax.block_until_ready(logits)
        ttft = time.perf_counter() - t0

        out = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
        t1 = time.perf_counter()
        pos = T0 + prefix
        for i in range(max_new_tokens - 1):
            logits, caches = self.decode_step(
                params, caches, out[-1][:, None], jnp.array(pos + i, jnp.int32))
            out.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
        jax.block_until_ready(out[-1])
        dt = time.perf_counter() - t1
        toks = jnp.stack(out, axis=1)
        return GenResult(tokens=toks, ttft_s=ttft, decode_s=dt,
                         tokens_per_s=B * max_new_tokens / max(dt, 1e-9))


class ContinuousBatchingEngine:
    """Continuous batching over a fixed pool of cache slots.

    The drain loop alternates two phases per clock tick:

    1. *admission* — while a slot is free and the FCFS queue head has
       arrived, prefill it (batch=1), scatter its cache into the slot, and
       record its first greedy token + TTFT;
    2. *decode* — one compiled step over all ``n_slots`` rows with per-slot
       ``(B,)`` position and token vectors; finished requests release their
       slot, which the next tick's admission phase can immediately reuse.

    Vacant slots decode garbage rows; their outputs are ignored and their
    cache rows are fully overwritten at the next insert, so they cost FLOPs
    but never correctness. Prefill compiles once per distinct prompt length
    (bucket prompts upstream if that matters).
    """

    def __init__(self, model, n_slots: int = 4, max_len: int = 512,
                 mp=None, donate: bool = False):
        if isinstance(model, EncDec):
            raise NotImplementedError(
                "continuous batching currently serves decoder-only LMs")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.mp = as_assignment(mp)
        d = (1,) if donate else ()
        self.prefill_step = jax.jit(make_prefill_step(model, mp=self.mp))
        self.decode_step = jax.jit(make_decode_step(model, mp=self.mp),
                                   donate_argnums=d)

    # ------------------------------------------------------------------
    def _admit(self, params, pool: CachePool, sched: Scheduler,
               results: dict, now: int) -> None:
        while pool.n_free:
            st = sched.pop_admissible(now)
            if st is None:
                return
            req = st.request
            assert req.prompt_len + req.max_new_tokens <= self.max_len, (
                f"request {req.rid}: {req.prompt_len}+{req.max_new_tokens} "
                f"exceeds pool max_len {self.max_len}")
            slot = pool.alloc()
            tokens = jnp.asarray(np.asarray(req.tokens, np.int32))[None]
            cache1 = self.model.init_cache(1, self.max_len)
            t0 = time.perf_counter()
            logits, cache1 = self.prefill_step(params, cache1,
                                               {"tokens": tokens})
            jax.block_until_ready(logits)
            ttft = time.perf_counter() - t0
            pool.insert(slot, cache1)
            first = int(jnp.argmax(logits[0, -1]))
            sched.start(st, slot, first, ttft, now)
            if st.done:                      # max_new_tokens == 1
                results[req.rid] = sched.finish(st, now)
                pool.free(slot)

    def serve(self, params, requests: Sequence[Request]) -> ServeSummary:
        """Drain ``requests`` (any arrival order) and return all results."""
        pool = CachePool(self.model, self.n_slots, self.max_len)
        sched = Scheduler()
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            sched.submit(r)

        results: dict = {}
        tok_host = np.zeros((self.n_slots, 1), np.int32)
        pos_host = np.zeros((self.n_slots,), np.int32)
        now = 0
        n_steps = 0
        decode_s = 0.0
        t_start = time.perf_counter()
        while sched.has_work():
            self._admit(params, pool, sched, results, now)
            if sched.running:
                tok_host[:] = 0
                pos_host[:] = 0
                for slot, st in sched.running.items():
                    tok_host[slot, 0] = st.last_token
                    pos_host[slot] = st.next_pos
                t0 = time.perf_counter()
                logits, pool.caches = self.decode_step(
                    params, pool.caches, jnp.asarray(tok_host),
                    jnp.asarray(pos_host))
                nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
                decode_s += time.perf_counter() - t0
                n_steps += 1
                for slot in list(sched.running):
                    st = sched.record_token(slot, int(nxt[slot]))
                    if st.done:
                        results[st.request.rid] = sched.finish(st, now)
                        pool.free(slot)
                now += 1
            else:
                # idle: jump the clock to the next arrival instead of spinning
                nxt_arrival = sched.next_arrival()
                if nxt_arrival is None:
                    break
                now = max(now + 1, nxt_arrival)

        total_s = time.perf_counter() - t_start
        # throughput over the decode phase only: each request's first token
        # comes out of its prefill, whose wall time is accounted as TTFT
        n_decoded = sum(max(len(r.tokens) - 1, 0) for r in results.values())
        return ServeSummary(results=results, n_steps=n_steps,
                            decode_s=decode_s, total_s=total_s,
                            tokens_per_s=(n_decoded / decode_s
                                          if decode_s > 0 else 0.0))
