"""Deterministic fault injection for the continuous serving stack.

The paper's contract is a *bound* (predicted loss-MSE <= tau), but bounds
are only as good as the runtime's ability to notice when reality violates
them. This module is the test side of that story: a seedable, fully
deterministic harness that injects every failure mode the engine's
hardening must contain, so each one is reproducible in unit tests and CI.

Fault classes (``FaultSpec.kind``):

``step_exception``
    The compiled step (decode or prefill, per ``phase``) raises before
    dispatch. Donation is off in the continuous engine, so the pool's
    caches are untouched; every affected request retries via the
    preemption/resume machinery.
``nan_page``
    NaN-poison the physical KV block behind ``(slot, page)`` of the live
    block table — the "corrupted shared page" scenario. Attention over the
    poisoned page turns the row's logits non-finite, which the engine's
    device-side tripwire flags on the next batched readback.
``nan_logits``
    NaN-poison one decode row's logits *after* the step — a saturating
    output-projection stand-in. Caught by the same tripwire.
``alloc_failure``
    ``ensure_block`` / ``ensure_range`` raises for the targeted slot —
    what a quarantine-shrunken pool does organically when reservations
    outrun surviving capacity.
``consumer_error`` / ``consumer_stall``
    The delivery path (consumer thread / sync deliver) raises or sleeps —
    a client that went away or stopped reading its stream.
``hung_step``
    The injector sleeps ``hang_s`` before the step dispatches, simulating
    a hung device step. Counted as a kernel fault; repeated kernel faults
    trigger the engine's fused -> gather paged-attention degradation.

Injection points are host-side hooks the engine/pool already pass through
(tick boundary, step dispatch, allocation, delivery), so the injector adds
zero device work when idle and the fault schedule is anchored to the
engine's deterministic step clock — ``step=k`` fires at the first
opportunity at or after clock tick ``k``, exactly once per spec.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["FAULT_KINDS", "FaultSpec", "FaultInjector", "InjectedFault",
           "poison_logit_rows"]

FAULT_KINDS = ("step_exception", "nan_page", "nan_logits", "alloc_failure",
               "consumer_error", "consumer_stall", "hung_step")


class InjectedFault(RuntimeError):
    """Raised by injector hooks; carries the spec that fired."""

    def __init__(self, msg: str, spec: "FaultSpec" = None):
        super().__init__(msg)
        self.spec = spec


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault. ``step`` is the engine's deterministic clock
    tick at (or after) which the fault arms; each spec fires exactly once.
    ``slot`` targets a decode row where that makes sense (``nan_page``,
    ``nan_logits``, ``alloc_failure``, ``consumer_error``); -1 matches any.
    ``page`` is the logical page ``nan_page`` poisons. ``phase`` scopes
    ``step_exception``/``hung_step`` to ``"decode"`` or ``"prefill"``."""
    kind: str
    step: int = 0
    slot: int = -1
    page: int = 0
    phase: str = "decode"
    hang_s: float = 0.01

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.phase not in ("decode", "prefill"):
            raise ValueError(f"phase must be decode|prefill: {self.phase!r}")
        if self.kind == "nan_page" and self.slot < 0:
            self.slot = 0            # a page poke needs a concrete target


class FaultInjector:
    """A deterministic schedule of :class:`FaultSpec` entries, consulted by
    the engine at its host-side hook points. ``fired`` tallies what actually
    triggered (kind -> count) so tests and CI can assert the schedule bit.
    """

    def __init__(self, specs: Sequence[FaultSpec]):
        self.specs = [s if isinstance(s, FaultSpec) else FaultSpec(**s)
                      for s in specs]
        self._pending = list(self.specs)
        self.fired: dict = {}
        self.now = -1
        # delivery hooks run on the consumer thread, the rest on the
        # producer: one lock keeps the pending list race-free
        self._lock = threading.Lock()

    # ---- schedule construction ---------------------------------------
    @classmethod
    def parse(cls, spec_str: str) -> "FaultInjector":
        """Build an injector from a CLI spec string::

            kind@step=3,slot=0,page=1;kind2@step=5,...

        Fields default as in :class:`FaultSpec`; values parse as int when
        they look like one, float otherwise (``hang_s``), str for ``phase``.
        """
        specs = []
        for part in spec_str.split(";"):
            part = part.strip()
            if not part:
                continue
            kind, _, rest = part.partition("@")
            kw = {}
            if rest:
                for field in rest.split(","):
                    k, eq, v = field.partition("=")
                    k = k.strip()
                    if not eq:
                        # bare-number shorthand: 'nan_page@3' == step=3
                        kw["step"] = int(k)
                    elif k in ("phase",):
                        kw[k] = v.strip()
                    elif k in ("hang_s",):
                        kw[k] = float(v)
                    else:
                        kw[k] = int(v)
            specs.append(FaultSpec(kind=kind.strip(), **kw))
        if not specs:
            raise ValueError(f"empty fault spec {spec_str!r}")
        return cls(specs)

    @classmethod
    def random(cls, seed: int, n_faults: int, *, max_step: int = 20,
               n_slots: int = 4, max_pages: int = 4,
               kinds: Sequence[str] = FAULT_KINDS) -> "FaultInjector":
        """A seeded random schedule for property tests: ``n_faults`` specs
        with kinds, steps, slots and pages drawn from a private PRNG —
        same seed, same schedule, byte for byte."""
        import random as _random
        rng = _random.Random(seed)
        specs = []
        for _ in range(n_faults):
            kind = rng.choice(list(kinds))
            specs.append(FaultSpec(
                kind=kind,
                step=rng.randrange(max_step),
                slot=rng.randrange(n_slots),
                page=rng.randrange(max_pages),
                phase=rng.choice(["decode", "prefill"]),
                hang_s=0.001))
        return cls(specs)

    # ---- engine hooks -------------------------------------------------
    def tick(self, now: int) -> None:
        self.now = now

    def _take(self, kind: str, *, phase: Optional[str] = None,
              slot: Optional[int] = None) -> Optional[FaultSpec]:
        """Pop the first pending spec of ``kind`` armed for the current
        clock (``spec.step <= now``) matching the phase/slot filters."""
        with self._lock:
            for sp in self._pending:
                if sp.kind != kind or sp.step > self.now:
                    continue
                if phase is not None and sp.phase != phase:
                    continue
                if slot is not None and sp.slot >= 0 and sp.slot != slot:
                    continue
                self._pending.remove(sp)
                self.fired[kind] = self.fired.get(kind, 0) + 1
                return sp
        return None

    def on_step(self, phase: str) -> Optional[str]:
        """Before a step dispatches. Raises :class:`InjectedFault` for an
        armed ``step_exception``; sleeps and returns ``"hung"`` for an
        armed ``hung_step``; returns None otherwise."""
        sp = self._take("hung_step", phase=phase)
        if sp is not None:
            time.sleep(sp.hang_s)
            return "hung"
        sp = self._take("step_exception", phase=phase)
        if sp is not None:
            raise InjectedFault(
                f"injected {phase} step exception at tick {self.now}", sp)
        return None

    def on_alloc(self, slot: int) -> None:
        """Before ``ensure_block``/``ensure_range`` for ``slot``."""
        sp = self._take("alloc_failure", slot=slot)
        if sp is not None:
            raise InjectedFault(
                f"injected allocation failure for slot {slot} at tick "
                f"{self.now}", sp)

    def take_poisons(self) -> list:
        """Every armed ``nan_page``/``nan_logits`` spec, popped. The engine
        applies them device-side at the tick boundary (pages) or to the
        step's output logits (rows)."""
        out = []
        while True:
            sp = self._take("nan_page") or self._take("nan_logits")
            if sp is None:
                return out
            out.append(sp)

    def on_deliver(self, rid: int, slot: int) -> None:
        """In the delivery path, before the streaming callback. Sleeps for
        an armed ``consumer_stall``; raises for ``consumer_error``."""
        sp = self._take("consumer_stall", slot=slot)
        if sp is not None:
            time.sleep(sp.hang_s)
        sp = self._take("consumer_error", slot=slot)
        if sp is not None:
            raise InjectedFault(
                f"injected consumer error for rid {rid} at tick "
                f"{self.now}", sp)

    @property
    def exhausted(self) -> bool:
        return not self._pending


@jax.jit
def poison_logit_rows(logits, mask):
    """NaN out the rows of ``logits`` (B, T, V) where ``mask`` (B,) is set —
    the injector's logit-poison primitive, applied after the step so the
    step's own numerics (and every other row) are untouched."""
    return jnp.where(mask[:, None, None], jnp.nan,
                     logits.astype(logits.dtype))
