"""Mesh-sharded serving: layout planning and state placement.

The continuous-batching engine becomes tensor-parallel here, not in the
model code: weights shard through the same logical-axis rules the trainer
uses (``param_shardings`` — with the divisibility fallback, so e.g. GQA
``kv_heads % model != 0`` replicates heads instead of failing), the paged
KV pool's block-major leaves shard over the mesh via their ``kv_blocks`` /
``kv_heads`` logical axes, and slot-major serving state (decode slots,
per-slot positions, block tables, sampled tokens) shards over ``data``.

One :class:`~repro.distributed.sharding.ServingMeshLayout` object describes
the whole arrangement. It is planned once per engine by
:func:`make_serving_layout` (delegating pool geometry to
``PagedCachePool.plan_blocks`` so the allocator and the layout can never
disagree), threaded to ``get_serving_step`` (which activates it at trace
time for the fused-kernel dispatch and runs every call under ``with
mesh:``), and handed to the cache pools for sharded placement.

Parity contract: sharded greedy tokens are bit-identical to the
single-device engine. The fused paged-attention kernel runs per-shard under
``shard_map`` with exactly the single-device per-row summation order; when
shapes don't divide the mesh it falls back to the gather path, which PR 5's
parity gate already pins to the kernel bitwise.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import sharding as shd
from repro.distributed.sharding import ServingMeshLayout
from repro.nn.spec import flatten_paths, tree_from_flat
from repro.serve.cache_pool import PagedCachePool

__all__ = ["make_serving_layout", "shard_serving_params", "shard_cache_tree",
           "data_sharding", "mesh_axis_sizes"]


def mesh_axis_sizes(mesh: Mesh) -> tuple:
    """(data, model) extents of a serving mesh; absent axes count as 1."""
    return (int(mesh.shape.get("data", 1)), int(mesh.shape.get("model", 1)))


def make_serving_layout(mesh: Optional[Mesh], *, n_slots: int, max_len: int,
                        block_size: int, n_blocks=None,
                        paged: bool = True) -> Optional[ServingMeshLayout]:
    """Plan how one engine's serving state spreads over ``mesh``.

    Returns None for ``mesh=None`` (the single-device engine, unchanged).
    The slot axis must divide ``data`` — slots are the unit of data
    parallelism and a ragged split would leave shards with unequal decode
    batches. Pool geometry (block count, page sharding, per-shard capacity)
    comes from ``PagedCachePool.plan_blocks`` so the host-side allocator and
    the device-side layout share one source of truth.
    """
    if mesh is None:
        return None
    data, model = mesh_axis_sizes(mesh)
    if n_slots % data != 0:
        raise ValueError(
            f"n_slots={n_slots} must divide the mesh's data axis ({data}): "
            f"decode slots shard over data")
    if not paged:
        return ServingMeshLayout(mesh=mesh, data=data, model=model,
                                 n_slots=n_slots, block_size=0, n_blocks=0,
                                 shard_pages=False, blocks_per_shard=0)
    n_blocks, shard_pages, bps = PagedCachePool.plan_blocks(
        n_slots, max_len, block_size, n_blocks=n_blocks, data_shards=data)
    return ServingMeshLayout(mesh=mesh, data=data, model=model,
                             n_slots=n_slots, block_size=block_size,
                             n_blocks=n_blocks, shard_pages=shard_pages,
                             blocks_per_shard=bps)


def shard_serving_params(model, params: dict, mesh: Mesh) -> dict:
    """Place a param pytree under the trainer's logical-axis rules
    (``kv_heads % model != 0`` and friends fall back to replication).
    ``device_put`` onto an already-correct sharding is a no-op, so calling
    this on every ``serve()`` is cheap after the first."""
    shardings = shd.param_shardings(model.param_specs(), mesh)
    flat = flatten_paths(params)
    return tree_from_flat(
        {p: jax.device_put(v, shardings[p]) for p, v in flat.items()})


def shard_cache_tree(model, caches: dict, flat_specs: dict,
                     mesh: Mesh) -> dict:
    """Place a materialized cache tree according to its specs' logical axes:
    paged K/V and MLA latents get ``kv_blocks``->data + ``kv_heads``->model
    (each with divisibility fallback), slot-major leaves (dense rings, SSM
    state) get ``act_batch``->data."""
    sh_tree = model.assemble_cache_tree(
        {k: NamedSharding(mesh, shd.partition_spec(s, mesh))
         for k, s in flat_specs.items()})
    return jax.tree.map(jax.device_put, caches, sh_tree)


def data_sharding(mesh: Mesh, ndim: int) -> NamedSharding:
    """Sharding for per-slot host vectors (tokens, positions, block tables):
    leading slot axis over ``data``, everything else replicated."""
    return NamedSharding(mesh, P(*(("data",) + (None,) * (ndim - 1))))
