"""Continuous-batching request scheduler (FCFS admission).

The scheduler is pure host-side bookkeeping: it owns the waiting queue and
the per-request prefill/decode state, and decides *which* request may enter
a cache slot at a given engine clock tick. All device work (prefill chunks,
batched decode) stays in the engine, so scheduling policy can evolve —
priority classes, preemption — without touching compiled code.

Admission emits *prefill work items* rather than running prefill inline: a
popped request parks in ``prefilling`` (slot -> state) with a
``prefill_pos`` cursor, the engine advances it chunk by chunk
(``prefill_advance``), and the final chunk's greedy token promotes it to
``running`` (``finish_prefill``). The engine's step loop arbitrates chunk
steps against decode steps under a TTFT-aware budget, so a long prompt
never head-of-line-blocks in-flight decodes.

The clock is abstract: the engine advances it once per decode step, and a
request becomes admissible when ``arrival <= now``. Driving admission off a
deterministic step clock (instead of wall time) is what makes "a late request
arrives mid-decode" reproducible in tests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestState", "RequestResult", "Scheduler"]

WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in engine clock ticks
    (decode steps); 0 means present from the start. ``timeout_steps``, if
    set, cancels the request (status ``"timeout"``) once the engine clock
    reaches ``arrival + timeout_steps`` before it finishes — step-based so
    timeout behavior is deterministic in tests."""
    rid: int
    tokens: np.ndarray                # (T,) int32 prompt
    max_new_tokens: int
    arrival: int = 0
    timeout_steps: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[0])


@dataclasses.dataclass
class RequestState:
    request: Request
    status: str = WAITING
    slot: int = -1
    next_pos: int = 0                 # cache position of the next decode write
    prefill_pos: int = 0              # prompt tokens already prefilled
    wall_admitted: float = 0.0        # engine-set perf_counter at admission
    last_token: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    ttft_s: float = 0.0
    admitted_step: int = -1
    finished_step: int = -1
    result_status: str = "ok"         # "ok" | "cancelled" | "timeout"

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.request.max_new_tokens


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                # (<= max_new_tokens,) greedy continuation
    ttft_s: float
    admitted_step: int
    finished_step: int
    status: str = "ok"                # "ok" | "cancelled" | "timeout"


class Scheduler:
    def __init__(self):
        self._queue: deque = deque()           # WAITING states, FCFS
        self.prefilling: dict = {}             # slot -> RequestState (FCFS order)
        self.running: dict = {}                # slot -> RequestState
        self.states: dict = {}                 # rid -> RequestState
        # backpressure signal: times the arrived queue head was held back by
        # the engine's resource gate (e.g. not enough free KV blocks)
        self.blocked_admissions = 0

    def submit(self, req: Request) -> RequestState:
        assert req.rid not in self.states, f"duplicate rid {req.rid}"
        st = RequestState(req)
        self.states[req.rid] = st
        self._queue.append(st)
        return st

    # ---- admission ----
    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self.prefilling)
                or bool(self.running))

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival among waiting requests (None if queue empty)."""
        return min((st.request.arrival for st in self._queue), default=None)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def pop_admissible(self, now: int, can_admit=None) -> Optional[RequestState]:
        """FCFS: the head of the queue, iff it has arrived by ``now`` and the
        resource gate accepts it. ``can_admit(request) -> bool`` is the
        engine's admission predicate (e.g. enough free KV blocks); a gated
        head blocks the whole queue — no skip-ahead — and that head-of-line
        wait is counted in ``blocked_admissions``."""
        if self._queue and self._queue[0].request.arrival <= now:
            if can_admit is None or can_admit(self._queue[0].request):
                return self._queue.popleft()
            self.blocked_admissions += 1
        return None

    # ---- chunked prefill lifecycle ----
    def start_prefill(self, st: RequestState, slot: int, now: int) -> None:
        """Claim ``slot`` for a request whose prompt will be prefilled in one
        or more chunk steps; the engine's step loop drives the chunks."""
        st.status = PREFILLING
        st.slot = slot
        st.prefill_pos = 0
        st.ttft_s = 0.0
        st.admitted_step = now
        self.prefilling[slot] = st

    def prefill_advance(self, slot: int, n_tokens: int,
                        dt_s: float) -> RequestState:
        """Record one completed chunk (``n_tokens`` prompt tokens) and fold
        its wall time into the request's TTFT. The engine overwrites
        ``ttft_s`` with the admission-to-first-token wall time when the
        final chunk lands (which also counts the decode steps interleaved
        between chunks); the chunk-dt sum here is the fallback for
        host-only scheduler use."""
        st = self.prefilling[slot]
        st.prefill_pos += n_tokens
        assert st.prefill_pos <= st.request.prompt_len, (
            st.prefill_pos, st.request.prompt_len)
        st.ttft_s += dt_s
        return st

    def finish_prefill(self, slot: int, first_token: int,
                       now: int) -> RequestState:
        """The final chunk produced the first greedy token: move to decode."""
        st = self.prefilling.pop(slot)
        st.status = RUNNING
        st.last_token = first_token
        st.out_tokens.append(first_token)
        st.next_pos = st.request.prompt_len
        self.running[slot] = st
        return st

    # ---- decode bookkeeping ----
    def record_token(self, slot: int, token: int) -> RequestState:
        st = self.running[slot]
        st.out_tokens.append(token)
        st.last_token = token
        st.next_pos += 1
        return st

    # ---- retirement ----
    def retire(self, st: RequestState, now: int,
               status: str = "ok") -> RequestState:
        """Drop ``st`` from the live sets and stamp its outcome, without
        materializing the result array. The async engine retires requests
        the moment their *step schedule* completes (token values may still
        be in flight to the host); :meth:`materialize` builds the
        ``RequestResult`` once every delivered value has landed."""
        if st.slot in self.running and self.running.get(st.slot) is st:
            del self.running[st.slot]
        if st.slot in self.prefilling and self.prefilling.get(st.slot) is st:
            del self.prefilling[st.slot]
        st.status = DONE
        st.finished_step = now
        st.result_status = status
        return st

    @staticmethod
    def materialize(st: RequestState) -> RequestResult:
        """Build the result record from a retired state. All token slots the
        request committed must be filled by now (no ``None`` placeholders)."""
        toks = st.out_tokens[:st.request.max_new_tokens]
        assert all(t is not None for t in toks), (
            f"rid {st.request.rid}: undelivered token placeholders at "
            f"materialize time (consumer did not drain?)")
        return RequestResult(
            rid=st.request.rid,
            tokens=np.asarray(toks, np.int32),
            ttft_s=st.ttft_s,
            admitted_step=st.admitted_step,
            finished_step=st.finished_step,
            status=st.result_status,
        )

    def finish(self, st: RequestState, now: int) -> RequestResult:
        return self.materialize(self.retire(st, now))

    # ---- cancellation ----
    def remove_waiting(self, rid: int) -> Optional[RequestState]:
        """Drop a still-queued request (cancellation before admission)."""
        for i, st in enumerate(self._queue):
            if st.request.rid == rid:
                del self._queue[i]
                return st
        return None
