"""Continuous-batching request scheduler (FCFS admission).

The scheduler is pure host-side bookkeeping: it owns the waiting queue and
the per-request decode state, and decides *which* request may enter a cache
slot at a given engine clock tick. All device work (prefill, slot scatter,
batched decode) stays in the engine, so scheduling policy can evolve —
priority classes, preemption, chunked prefill — without touching compiled
code.

The clock is abstract: the engine advances it once per decode step, and a
request becomes admissible when ``arrival <= now``. Driving admission off a
deterministic step clock (instead of wall time) is what makes "a late request
arrives mid-decode" reproducible in tests.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestState", "RequestResult", "Scheduler"]

WAITING = "waiting"
RUNNING = "running"
DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in engine clock ticks
    (decode steps); 0 means present from the start."""
    rid: int
    tokens: np.ndarray                # (T,) int32 prompt
    max_new_tokens: int
    arrival: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[0])


@dataclasses.dataclass
class RequestState:
    request: Request
    status: str = WAITING
    slot: int = -1
    next_pos: int = 0                 # cache position of the next decode write
    last_token: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    ttft_s: float = 0.0
    admitted_step: int = -1
    finished_step: int = -1

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.request.max_new_tokens


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                # (max_new_tokens,) greedy continuation
    ttft_s: float
    admitted_step: int
    finished_step: int


class Scheduler:
    def __init__(self):
        self._queue: deque = deque()           # WAITING states, FCFS
        self.running: dict = {}                # slot -> RequestState
        self.states: dict = {}                 # rid -> RequestState
        # backpressure signal: times the arrived queue head was held back by
        # the engine's resource gate (e.g. not enough free KV blocks)
        self.blocked_admissions = 0

    def submit(self, req: Request) -> RequestState:
        assert req.rid not in self.states, f"duplicate rid {req.rid}"
        st = RequestState(req)
        self.states[req.rid] = st
        self._queue.append(st)
        return st

    # ---- admission ----
    def has_work(self) -> bool:
        return bool(self._queue) or bool(self.running)

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival among waiting requests (None if queue empty)."""
        return min((st.request.arrival for st in self._queue), default=None)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def pop_admissible(self, now: int, can_admit=None) -> Optional[RequestState]:
        """FCFS: the head of the queue, iff it has arrived by ``now`` and the
        resource gate accepts it. ``can_admit(request) -> bool`` is the
        engine's admission predicate (e.g. enough free KV blocks); a gated
        head blocks the whole queue — no skip-ahead — and that head-of-line
        wait is counted in ``blocked_admissions``."""
        if self._queue and self._queue[0].request.arrival <= now:
            if can_admit is None or can_admit(self._queue[0].request):
                return self._queue.popleft()
            self.blocked_admissions += 1
        return None

    def start(self, st: RequestState, slot: int, first_token: int,
              ttft_s: float, now: int) -> None:
        """Mark a prefilled request as occupying ``slot``."""
        st.status = RUNNING
        st.slot = slot
        st.last_token = first_token
        st.out_tokens.append(first_token)
        st.next_pos = st.request.prompt_len
        st.ttft_s = ttft_s
        st.admitted_step = now
        self.running[slot] = st

    # ---- decode bookkeeping ----
    def record_token(self, slot: int, token: int) -> RequestState:
        st = self.running[slot]
        st.out_tokens.append(token)
        st.last_token = token
        st.next_pos += 1
        return st

    def finish(self, st: RequestState, now: int) -> RequestResult:
        if st.slot in self.running:
            del self.running[st.slot]
        st.status = DONE
        st.finished_step = now
        return RequestResult(
            rid=st.request.rid,
            tokens=np.asarray(st.out_tokens[:st.request.max_new_tokens],
                              np.int32),
            ttft_s=st.ttft_s,
            admitted_step=st.admitted_step,
            finished_step=now,
        )
