"""Continuous-batching request scheduler (priority admission + preemption).

The scheduler is pure host-side bookkeeping: it owns the waiting queue and
the per-request prefill/decode state, and decides *which* request may enter
a cache slot at a given engine clock tick. All device work (prefill chunks,
batched decode) stays in the engine, so scheduling policy can evolve —
priority classes, preemption — without touching compiled code.

Admission order is by priority class (higher first), then earliest arrival,
then submission order — at uniform priority this degenerates to exactly the
old FCFS queue. The resource gate still applies only to the *best* arrived
candidate (no skip-ahead: a gated head blocks the queue and is counted in
``blocked_admissions``), which keeps backpressure semantics deterministic.
On top of that, the engine may **preempt**: when the best waiting request
outranks a live one and the gate is blocking, :meth:`preempt_candidate`
names the victim (lowest priority, then latest admitted, then highest
slot), and :meth:`preempt` re-queues it with ``resume_tokens`` = prompt +
every token generated so far. Re-prefilling that effective prompt replays
the victim's state bit-exactly (per-token quant scales make K/V a pure
function of the prefix), and with prefix caching on, its blocks are still
resident, so the resume costs one tail chunk.

Admission emits *prefill work items* rather than running prefill inline: a
popped request parks in ``prefilling`` (slot -> state) with a
``prefill_pos`` cursor, the engine advances it chunk by chunk
(``prefill_advance``), and the final chunk's greedy token promotes it to
``running`` (``finish_prefill``). The engine's step loop arbitrates chunk
steps against decode steps under a TTFT-aware budget, so a long prompt
never head-of-line-blocks in-flight decodes.

The clock is abstract: the engine advances it once per decode step, and a
request becomes admissible when ``arrival <= now``. Driving admission off a
deterministic step clock (instead of wall time) is what makes "a late request
arrives mid-decode" reproducible in tests.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Optional

import numpy as np

__all__ = ["Request", "RequestState", "RequestResult", "Scheduler"]

WAITING = "waiting"
PREFILLING = "prefilling"
RUNNING = "running"
DONE = "done"


@dataclasses.dataclass
class Request:
    """One generation request. ``arrival`` is in engine clock ticks
    (decode steps); 0 means present from the start. ``timeout_steps``, if
    set, cancels the request (status ``"timeout"``) once the engine clock
    reaches ``arrival + timeout_steps`` before it finishes — step-based so
    timeout behavior is deterministic in tests. ``priority`` is the
    admission/preemption class: higher admits first, and only a strictly
    higher-priority waiter may evict a live request."""
    rid: int
    tokens: np.ndarray                # (T,) int32 prompt
    max_new_tokens: int
    arrival: int = 0
    timeout_steps: Optional[int] = None
    priority: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).shape[0])


@dataclasses.dataclass(eq=False)     # identity equality: queue removal must
class RequestState:                  # never field-compare numpy token arrays
    request: Request
    status: str = WAITING
    slot: int = -1
    next_pos: int = 0                 # cache position of the next decode write
    prefill_pos: int = 0              # prompt tokens already prefilled
    wall_admitted: float = 0.0        # engine-set perf_counter at admission
    last_token: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    ttft_s: float = 0.0
    admitted_step: int = -1
    first_token_step: int = -1        # engine clock when token 0 landed
    finished_step: int = -1
    # "ok" | "cancelled" | "timeout" | "retried" (completed after >= 1
    # fault retry) | "failed" (retry budget exhausted; tokens are the
    # last-known-good prefix)
    result_status: str = "ok"
    # preemption/resume: after an eviction the request re-prefills prompt +
    # everything it had generated (its *effective* prompt) and keeps
    # decoding where it left off
    resume_tokens: Optional[np.ndarray] = None
    n_preempted: int = 0
    digests: Optional[list] = None    # engine-cached prefix chain digests
    # fault containment: the consumer's tripwire stamps the index of the
    # first token produced from non-finite logits (tokens before it are
    # good); the engine truncates there and retries via resume. fault_kind
    # labels the cause for the counters.
    fault_idx: Optional[int] = None
    fault_kind: Optional[str] = None
    n_retries: int = 0
    _seq: int = -1                    # submission order (queue tiebreak)

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.request.max_new_tokens

    @property
    def effective_tokens(self) -> np.ndarray:
        """What prefill must process: the original prompt, or — after a
        preemption — prompt + all generated tokens."""
        return (self.request.tokens if self.resume_tokens is None
                else self.resume_tokens)

    @property
    def effective_prompt_len(self) -> int:
        return int(np.asarray(self.effective_tokens).shape[0])

    @property
    def remaining_new_tokens(self) -> int:
        """Decode steps still owed. The resumed prefill's final chunk
        produces the next token, so ``effective_prompt_len +
        remaining_new_tokens - 1`` never exceeds ``prompt_len +
        max_new_tokens - 1`` — the block budget is preemption-invariant."""
        return max(self.request.max_new_tokens - len(self.out_tokens), 0)


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray                # (<= max_new_tokens,) greedy continuation
    ttft_s: float
    admitted_step: int
    finished_step: int
    # "ok" | "cancelled" | "timeout" | "retried" | "failed" — "retried"
    # means the request completed (all max_new_tokens, bit-identical to a
    # fault-free run) after >= 1 fault-containment retry; "failed" means
    # the retry budget ran out and ``tokens`` holds the last-known-good
    # prefix produced before the fault
    status: str = "ok"
    # engine clock tick at which the first token was produced; with arrival
    # this gives a deterministic step-clock TTFT (first_token_step -
    # arrival), the unit the adaptive-tau SLA benchmarks price
    first_token_step: int = -1
    retries: int = 0                  # fault-containment retries consumed


class Scheduler:
    def __init__(self):
        self._queue: list = []                 # WAITING states, priority order
        self._next_seq = 0
        self.prefilling: dict = {}             # slot -> RequestState
        self.running: dict = {}                # slot -> RequestState
        self.states: dict = {}                 # rid -> RequestState
        # backpressure signal: times the arrived queue head was held back by
        # the engine's resource gate (e.g. not enough free KV blocks)
        self.blocked_admissions = 0
        self.preemptions = 0

    @staticmethod
    def _qkey(st: RequestState):
        return (-st.request.priority, st.request.arrival, st._seq)

    def _enqueue(self, st: RequestState) -> None:
        bisect.insort(self._queue, st, key=self._qkey)

    def submit(self, req: Request) -> RequestState:
        assert req.rid not in self.states, f"duplicate rid {req.rid}"
        st = RequestState(req)
        st._seq = self._next_seq
        self._next_seq += 1
        self.states[req.rid] = st
        self._enqueue(st)
        return st

    # ---- admission ----
    def has_work(self) -> bool:
        return (bool(self._queue) or bool(self.prefilling)
                or bool(self.running))

    def next_arrival(self) -> Optional[int]:
        """Earliest arrival among waiting requests (None if queue empty)."""
        return min((st.request.arrival for st in self._queue), default=None)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def _best_arrived(self, now: int) -> Optional[RequestState]:
        for st in self._queue:
            if st.request.arrival <= now:
                return st
        return None

    def peek_admissible(self, now: int) -> Optional[RequestState]:
        """The request :meth:`pop_admissible` would consider at ``now``
        (highest priority among arrived, FCFS within a class), without
        popping or gating it — the engine's preemption decision looks at
        this to ask whether the best waiter outranks a live slot."""
        return self._best_arrived(now)

    def pop_admissible(self, now: int, can_admit=None) -> Optional[RequestState]:
        """The best arrived request — priority class first, FCFS within a
        class — iff the resource gate accepts it. ``can_admit(request) ->
        bool`` is the engine's admission predicate (e.g. enough free KV
        blocks); a gated best candidate blocks the whole queue — no
        skip-ahead — and that head-of-line wait is counted in
        ``blocked_admissions``. At uniform priority this is exactly the old
        FCFS pop."""
        st = self._best_arrived(now)
        if st is not None:
            if can_admit is None or can_admit(st.request):
                self._queue.remove(st)
                return st
            self.blocked_admissions += 1
        return None

    # ---- preemption ----
    def preempt_candidate(self, min_priority: int) -> Optional[RequestState]:
        """The live (prefilling or running) request a strictly
        higher-priority waiter should evict: lowest priority first, then
        latest admitted, then highest slot — the cheapest progress to
        throw away, and deterministic. None when every live request has
        ``priority >= min_priority`` (equal priority never preempts, so
        two classes can't thrash each other)."""
        live = list(self.prefilling.values()) + list(self.running.values())
        live = [st for st in live if st.request.priority < min_priority]
        if not live:
            return None
        return max(live, key=lambda st: (-st.request.priority,
                                         st.admitted_step, st.slot))

    def preempt(self, st: RequestState, now: int) -> RequestState:
        """Evict a live request back to the waiting queue. Its effective
        prompt becomes prompt + every token generated so far (all token
        values must have landed — the engine flushes in-flight deliveries
        first), so the resumed prefill replays its state bit-exactly and
        its final chunk produces the *next* token via the normal
        finish-prefill path."""
        assert st.status in (PREFILLING, RUNNING), st.status
        if self.prefilling.get(st.slot) is st:
            del self.prefilling[st.slot]
        if self.running.get(st.slot) is st:
            del self.running[st.slot]
        assert all(t is not None for t in st.out_tokens), (
            f"rid {st.request.rid}: preempted with undelivered tokens")
        st.resume_tokens = np.concatenate([
            np.asarray(st.request.tokens, np.int32),
            np.asarray(st.out_tokens, np.int32)])
        st.digests = None                 # effective prompt changed
        st.status = WAITING
        st.slot = -1
        st.prefill_pos = 0
        st.n_preempted += 1
        self.preemptions += 1
        self._enqueue(st)                 # original seq: FCFS slot preserved
        return st

    # ---- fault containment ----
    def requeue_for_retry(self, st: RequestState, now: int) -> RequestState:
        """Bounded-retry resume after fault containment: like
        :meth:`preempt`, but the engine has already waited out in-flight
        deliveries, truncated the poisoned token tail (``fault_idx``) and
        released the slot — all that remains here is rebuilding the
        effective prompt from the surviving last-known-good prefix and
        re-queueing. Because resume is bit-exact, a retried request that
        completes is bit-identical to a fault-free run."""
        assert st.status != WAITING, st.status
        if self.prefilling.get(st.slot) is st:
            del self.prefilling[st.slot]
        if self.running.get(st.slot) is st:
            del self.running[st.slot]
        assert all(t is not None for t in st.out_tokens), (
            f"rid {st.request.rid}: retried with undelivered tokens")
        st.resume_tokens = np.concatenate([
            np.asarray(st.request.tokens, np.int32),
            np.asarray(st.out_tokens, np.int32)]) if st.out_tokens else None
        st.digests = None
        st.status = WAITING
        st.slot = -1
        st.prefill_pos = 0
        if not st.out_tokens:             # first token itself was poisoned
            st.first_token_step = -1
        st.fault_idx = None
        st.fault_kind = None
        st.n_retries += 1
        self._enqueue(st)
        return st

    # ---- chunked prefill lifecycle ----
    def start_prefill(self, st: RequestState, slot: int, now: int,
                      start_at: int = 0) -> None:
        """Claim ``slot`` for a request whose (effective) prompt will be
        prefilled in one or more chunk steps; the engine's step loop drives
        the chunks. ``start_at`` > 0 skips a cached prefix — those tokens'
        KV blocks are already mapped into the slot's table."""
        st.status = PREFILLING
        st.slot = slot
        st.prefill_pos = start_at
        if not st.out_tokens:             # a resumed request keeps its TTFT
            st.ttft_s = 0.0
        if st.admitted_step < 0:          # first admission only
            st.admitted_step = now
        self.prefilling[slot] = st

    def prefill_advance(self, slot: int, n_tokens: int,
                        dt_s: float) -> RequestState:
        """Record one completed chunk (``n_tokens`` prompt tokens) and fold
        its wall time into the request's TTFT. The engine overwrites
        ``ttft_s`` with the admission-to-first-token wall time when the
        final chunk lands (which also counts the decode steps interleaved
        between chunks); the chunk-dt sum here is the fallback for
        host-only scheduler use."""
        st = self.prefilling[slot]
        st.prefill_pos += n_tokens
        assert st.prefill_pos <= st.effective_prompt_len, (
            st.prefill_pos, st.effective_prompt_len)
        st.ttft_s += dt_s
        return st

    def finish_prefill(self, slot: int, first_token: int,
                       now: int) -> RequestState:
        """The final chunk produced the next greedy token: move to decode.
        For a fresh request that token is the first; for a resumed one it
        continues wherever the eviction cut off."""
        st = self.prefilling.pop(slot)
        st.status = RUNNING
        st.last_token = first_token
        st.out_tokens.append(first_token)
        if st.first_token_step < 0:   # a resumed request keeps its stamp
            st.first_token_step = now
        st.next_pos = st.effective_prompt_len
        self.running[slot] = st
        return st

    # ---- decode bookkeeping ----
    def record_token(self, slot: int, token: int) -> RequestState:
        st = self.running[slot]
        st.out_tokens.append(token)
        st.last_token = token
        st.next_pos += 1
        return st

    # ---- retirement ----
    def retire(self, st: RequestState, now: int,
               status: str = "ok") -> RequestState:
        """Drop ``st`` from the live sets and stamp its outcome, without
        materializing the result array. The async engine retires requests
        the moment their *step schedule* completes (token values may still
        be in flight to the host); :meth:`materialize` builds the
        ``RequestResult`` once every delivered value has landed."""
        if st.slot in self.running and self.running.get(st.slot) is st:
            del self.running[st.slot]
        if st.slot in self.prefilling and self.prefilling.get(st.slot) is st:
            del self.prefilling[st.slot]
        st.status = DONE
        st.finished_step = now
        if status == "ok" and st.n_retries > 0:
            status = "retried"    # completed, but only after containment
        st.result_status = status
        return st

    @staticmethod
    def materialize(st: RequestState) -> RequestResult:
        """Build the result record from a retired state. All token slots the
        request committed must be filled by now (no ``None`` placeholders)."""
        toks = st.out_tokens[:st.request.max_new_tokens]
        assert all(t is not None for t in toks), (
            f"rid {st.request.rid}: undelivered token placeholders at "
            f"materialize time (consumer did not drain?)")
        return RequestResult(
            rid=st.request.rid,
            tokens=np.asarray(toks, np.int32),
            ttft_s=st.ttft_s,
            admitted_step=st.admitted_step,
            finished_step=st.finished_step,
            status=st.result_status,
            first_token_step=st.first_token_step,
            retries=st.n_retries,
        )

    def finish(self, st: RequestState, now: int) -> RequestResult:
        return self.materialize(self.retire(st, now))

    # ---- cancellation ----
    def remove_waiting(self, rid: int) -> Optional[RequestState]:
        """Drop a still-queued request (cancellation before admission)."""
        for i, st in enumerate(self._queue):
            if st.request.rid == rid:
                del self._queue[i]
                return st
        return None
