"""Optimizers with spec-aware (ZeRO-shardable) state.

AdamW for standard scales; Adafactor (factored second moment, no first
moment) for >=100B-param configs where full Adam state cannot fit v5e HBM —
the selection rule lives in ``select_optimizer``. State layouts are derived
from the model's ParamSpecs so the launcher can assign ZeRO-1 shardings to
the moments without materializing them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.spec import ParamSpec

__all__ = ["OptConfig", "select_optimizer", "init_state", "state_specs",
           "apply_updates", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    # adafactor
    decay_offset: float = 1e-30


def select_optimizer(n_params: int, base: Optional[OptConfig] = None) -> OptConfig:
    base = base or OptConfig()
    if n_params >= 100e9 and base.name == "adamw":
        return dataclasses.replace(base, name="adafactor")
    return base


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------


def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def state_specs(param_specs: dict, cfg: OptConfig) -> dict:
    """Flat path->ParamSpec dict of optimizer-state tensors."""
    out: dict = {"step": ParamSpec((), (), jnp.int32, "zeros")}
    for path, ps in param_specs.items():
        if cfg.name == "adamw":
            out[f"mu/{path}"] = ParamSpec(ps.shape, ps.logical_axes,
                                          jnp.float32, "zeros")
            out[f"nu/{path}"] = ParamSpec(ps.shape, ps.logical_axes,
                                          jnp.float32, "zeros")
        else:  # adafactor: row/col second-moment factors
            if _factored(ps.shape):
                out[f"vr/{path}"] = ParamSpec(ps.shape[:-1],
                                              ps.logical_axes[:-1],
                                              jnp.float32, "zeros")
                out[f"vc/{path}"] = ParamSpec(ps.shape[:-2] + ps.shape[-1:],
                                              ps.logical_axes[:-2]
                                              + ps.logical_axes[-1:],
                                              jnp.float32, "zeros")
            else:
                out[f"v/{path}"] = ParamSpec(ps.shape, ps.logical_axes,
                                             jnp.float32, "zeros")
    return out


def init_state(param_specs: dict, cfg: OptConfig) -> dict:
    from repro.nn.spec import tree_from_flat
    flat = {}
    for path, ps in state_specs(param_specs, cfg).items():
        flat[path] = jnp.zeros(ps.shape, ps.dtype)
    return tree_from_flat(flat)


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------


def _global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# leaves larger than this get their update lax.map'ed over the leading
# (stacked-layers) dim, bounding fp32 optimizer temporaries to one slice
_CHUNKED_UPDATE_BYTES = 256 * 1024 * 1024


def _update_one(cfg: OptConfig, step, lr, scale, p, g, st: dict) -> tuple:
    """Elementwise optimizer math for one param (or one stacked slice).

    Returns (new_p, new_state_parts).
    """
    g = g.astype(jnp.float32) * scale
    pf = p.astype(jnp.float32)
    out_s = {}
    if cfg.name == "adamw":
        mu = cfg.b1 * st["mu"] + (1 - cfg.b1) * g
        nu = cfg.b2 * st["nu"] + (1 - cfg.b2) * jnp.square(g)
        t = step.astype(jnp.float32)
        mu_hat = mu / (1 - cfg.b1 ** t)
        nu_hat = nu / (1 - cfg.b2 ** t)
        upd = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        out_s["mu"] = mu
        out_s["nu"] = nu
    else:  # adafactor (no first moment)
        b2 = 1.0 - (step.astype(jnp.float32) ** -0.8)
        g2 = jnp.square(g) + cfg.decay_offset
        if "vr" in st:
            vr = b2 * st["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * st["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            out_s["vr"] = vr
            out_s["vc"] = vc
            rmean = jnp.mean(vr, axis=-1, keepdims=True)
            vhat = (vr / jnp.maximum(rmean, 1e-30))[..., None] \
                * vc[..., None, :]
        else:
            v = b2 * st["v"] + (1 - b2) * g2
            out_s["v"] = v
            vhat = v
        upd = g * jax.lax.rsqrt(jnp.maximum(vhat, 1e-30))
        # relative update clipping (Adafactor d=1.0; per-slice when chunked)
        rms = jnp.sqrt(jnp.mean(jnp.square(upd)) + 1e-30)
        upd = upd / jnp.maximum(1.0, rms)
    if cfg.weight_decay and p.ndim >= 2:
        upd = upd + cfg.weight_decay * pf
    return (pf - lr * upd).astype(p.dtype), out_s


def apply_updates(params: dict, grads: dict, state: dict,
                  cfg: OptConfig) -> tuple:
    """Returns (new_params, new_state, metrics)."""
    from repro.nn.spec import flatten_paths, tree_from_flat

    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0

    fp = flatten_paths(params)
    fg = flatten_paths(grads)
    fs = flatten_paths(state)
    new_p, new_s = {}, {"step": step}

    for path, p in fp.items():
        st = {pre: fs[f"{pre}/{path}"] for pre in ("mu", "nu", "vr", "vc", "v")
              if f"{pre}/{path}" in fs}
        # vr/vc state only counts as factored if the slice stays >= 2D
        chunk = (p.nbytes > _CHUNKED_UPDATE_BYTES and p.ndim >= 3
                 and p.shape[0] > 1
                 and all(s.shape[:1] == p.shape[:1] for s in st.values()))
        if chunk:
            np_, ns_ = jax.lax.map(
                lambda args: _update_one(cfg, step, lr, scale, args[0],
                                         args[1], args[2]),
                (p, fg[path], st))
        else:
            np_, ns_ = _update_one(cfg, step, lr, scale, p, fg[path], st)
        new_p[path] = np_
        for k, v in ns_.items():
            new_s[f"{k}/{path}"] = v

    metrics = {"lr": lr, "grad_norm": gnorm}
    return tree_from_flat(new_p), tree_from_flat(new_s), metrics
