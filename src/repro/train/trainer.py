"""Training loop with checkpoint/restart, straggler watchdog and metrics.

Fault-tolerance contract:
* auto-resume from the latest digest-valid checkpoint (params + optimizer +
  step); the data stream is step-seeded so a restart reproduces it exactly;
* atomic checkpoints every ``ckpt_every`` steps (CheckpointManager);
* straggler watchdog: a step exceeding ``step_time_budget`` x median emits a
  warning record, forces a checkpoint at the next boundary and (optionally)
  aborts with exit code 17 so the cluster manager reschedules the job —
  restart-on-straggler is the standard mitigation when a host degrades;
* elastic: restore re-shards onto whatever mesh the new process builds.
"""
from __future__ import annotations

import dataclasses
import json
import os
import statistics
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import CheckpointManager
from repro.distributed import sharding as shd
from repro.launch.steps import make_train_step
from repro.nn.spec import flatten_paths
from repro.train import optim

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_n: int = 3
    log_every: int = 10
    n_microbatches: int = 1
    step_time_budget: float = 5.0      # x median -> straggler
    abort_on_straggler: bool = False
    metrics_path: Optional[str] = None


class Trainer:
    def __init__(self, model, opt_cfg: optim.OptConfig, mesh,
                 cfg: TrainerConfig, mp: Optional[dict] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.cfg = cfg
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.keep_n)
        self.specs = model.param_specs()
        self.p_sh = shd.param_shardings(self.specs, mesh)
        self.s_specs = optim.state_specs(self.specs, opt_cfg)
        self.s_sh = shd.param_shardings(self.s_specs, mesh, zero=True)
        step_fn = make_train_step(model, opt_cfg,
                                  n_microbatches=cfg.n_microbatches, mp=mp)
        self.train_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self._step_times: list = []

    # ------------------------------------------------------------------
    def init_or_resume(self, init_key) -> tuple:
        """Returns (start_step, params, opt_state)."""
        latest = self.ckpt.latest_valid_step()
        if latest is not None:
            shardings = {**{f"params/{k}": s for k, s in self.p_sh.items()},
                         **{f"opt/{k}": s for k, s in self.s_sh.items()}}
            step, tree, _ = self.ckpt.restore(latest, shardings)
            return step, tree["params"], tree["opt"]
        with self.mesh:
            params = self._init_sharded(init_key)
            opt_state = self._init_opt_sharded()
        return 0, params, opt_state

    def _init_sharded(self, key):
        from repro.nn.spec import tree_from_flat
        params = self.model.init(key)
        flat = flatten_paths(params)
        out = {p: jax.device_put(v, self.p_sh[p]) for p, v in flat.items()}
        return tree_from_flat(out)

    def _init_opt_sharded(self):
        from repro.nn.spec import tree_from_flat
        state = optim.init_state(self.specs, self.opt_cfg)
        flat = flatten_paths(state)
        out = {p: jax.device_put(v, self.s_sh[p]) for p, v in flat.items()}
        return tree_from_flat(out)

    # ------------------------------------------------------------------
    def _log(self, rec: dict) -> None:
        if self.cfg.metrics_path:
            os.makedirs(os.path.dirname(self.cfg.metrics_path) or ".",
                        exist_ok=True)
            with open(self.cfg.metrics_path, "a") as f:
                f.write(json.dumps(rec) + "\n")

    def _watchdog(self, dt: float, step: int) -> bool:
        """Returns True if this step is a straggler."""
        self._step_times.append(dt)
        hist = self._step_times[-50:]
        if len(hist) < 5:
            return False
        med = statistics.median(hist[:-1])
        if dt > self.cfg.step_time_budget * med:
            self._log({"event": "straggler", "step": step, "dt": dt,
                       "median": med})
            return True
        return False

    # ------------------------------------------------------------------
    def fit(self, data, start_key=None, eval_fn: Optional[Callable] = None):
        start_key = start_key if start_key is not None else jax.random.key(0)
        step, params, opt_state = self.init_or_resume(start_key)
        last_loss = None
        force_ckpt = False
        with self.mesh:
            while step < self.cfg.total_steps:
                batch = data.batch_at(step)
                t0 = time.time()
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                step += 1
                straggler = self._watchdog(dt, step)
                force_ckpt |= straggler
                if step % self.cfg.log_every == 0 or step == 1:
                    rec = {"step": step, "loss": loss, "dt": round(dt, 4),
                           "lr": float(metrics["lr"]),
                           "grad_norm": float(metrics["grad_norm"])}
                    self._log(rec)
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"dt {dt*1e3:7.1f}ms gnorm {rec['grad_norm']:.3f}",
                          flush=True)
                if step % self.cfg.ckpt_every == 0 or force_ckpt \
                        or step == self.cfg.total_steps:
                    self.ckpt.save(step, {"params": params, "opt": opt_state},
                                   extra={"loss": loss})
                    force_ckpt = False
                    if straggler and self.cfg.abort_on_straggler:
                        raise SystemExit(17)
                last_loss = loss
        if eval_fn is not None:
            eval_fn(params)
        return params, opt_state, last_loss
