import jax
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests must see the
# real (single) device; only launch/dryrun.py forces 512 placeholder devices.

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.key(0)
