"""Load-adaptive mixed precision (solver <-> scheduler loop): controller
hysteresis/dwell/cadence properties, the bundle registry (round-trip,
fingerprint and calib-hash rejection, freshest-wins), the measured
wall-clock gain tier, engine-level plan-swap parity (a never-firing
controller and a mid-stream swap to the *same* plan are both bit-identical
to a fixed-plan engine), cross-drain prefix-index persistence +
swap-invalidation, scaled fp8 KV calibration with its loss-MSE accuracy
gate, and the dense chunked-prefill sliding-window ring regression."""
import copy
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mpconfig import MPPlan
from repro.core.pipeline import (AMPOptions, CalibrationBundle, calibrate,
                                 tabulate_measured_gains,
                                 _params_fingerprint)
from repro.core.registry import BundleRegistry, _safe
from repro.models.registry import get_model
from repro.quant.kv_scales import FP8_E4M3_MAX, calibrate_kv_scales
from repro.quant.qops import QuantContext
from repro.serve import (AdaptiveMPController, ContinuousBatchingEngine,
                         Request, ServeEngine)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False

MP_ASSIGNMENT = {
    "layers/0/attn/q_proj": "fp8_e4m3",
    "layers/1/mlp/down_proj": "fp8_e4m3",
    "lm_head": "fp8_e4m3",
}


class FakeBundle:
    """Counts solves; the controller never inspects the plan it returns."""

    def __init__(self, plans=None):
        self.plans = plans           # optional tau -> assignment dict
        self.solved = []

    def solve(self, tau=None, objective=None, **kw):
        self.solved.append((tau, objective))
        if self.plans is not None:
            return dict(self.plans[tau])
        return {"tau": tau, "objective": objective}


def _ctrl(**kw):
    base = dict(bundle=FakeBundle(), taus=(0.01, 0.02, 0.04), every=1,
                dwell=0, queue_high=4, queue_low=0)
    base.update(kw)
    return AdaptiveMPController(**base)


HOT = dict(queue_depth=99, blocked=0, occupancy=1.0)
COOL = dict(queue_depth=0, blocked=0, occupancy=0.0)
HOLD = dict(queue_depth=2, blocked=0, occupancy=0.7)  # between the bands


# ---------------------------------------------------------------------------
# controller properties
# ---------------------------------------------------------------------------


def test_controller_validation():
    with pytest.raises(ValueError, match="ascend"):
        _ctrl(taus=(0.02, 0.01))
    with pytest.raises(ValueError, match="at least one"):
        _ctrl(taus=())
    with pytest.raises(ValueError):
        _ctrl(every=0)
    with pytest.raises(ValueError):
        _ctrl(dwell=-1)
    with pytest.raises(ValueError, match="low <= high"):
        _ctrl(queue_high=1, queue_low=2)
    with pytest.raises(ValueError, match="low <= high"):
        _ctrl(occ_high=0.3, occ_low=0.5)
    # equal taus are a legal ladder (a swap to the same plan is a no-op
    # plan-wise but still exercises the full swap machinery)
    _ctrl(taus=(0.01, 0.01))


def test_from_bundle_geometric_ladder():
    c = AdaptiveMPController.from_bundle(FakeBundle(), 0.01, n_levels=3,
                                         factor=2.0)
    np.testing.assert_allclose(c.taus, (0.01, 0.02, 0.04))
    assert c.level == 0 and c.tau == 0.01
    with pytest.raises(AssertionError):
        AdaptiveMPController.from_bundle(FakeBundle(), 0.01, factor=1.0)


def test_escalate_restore_and_hold():
    c = _ctrl()
    assert c.observe(0, **HOT) is not None
    assert (c.level, c.downshifts, c.restores) == (1, 1, 0)
    assert c.observe(1, **HOLD) is None         # between bands: hold
    assert c.level == 1
    assert c.observe(2, **COOL) is not None
    assert (c.level, c.downshifts, c.restores) == (0, 1, 1)
    # at the base plan a cool signal has nowhere to go
    assert c.observe(3, **COOL) is None
    assert c.restores == 1


def test_one_level_per_evaluation():
    c = _ctrl()
    for t in range(3):
        c.observe(t, **HOT)
    assert c.level == 2                          # 0 -> 1 -> 2, never a jump
    assert [lvl for _, lvl, _ in c.history] == [1, 2]


def test_cadence_skips_ticks_but_keeps_blocked_signal():
    c = _ctrl(every=4)
    assert c.observe(0, **COOL) is None          # evaluates; nothing to do
    # ticks 1..3 are off-cadence: no evaluation even under a hot signal
    for t in (1, 2, 3):
        assert c.observe(t, **HOT) is None
    assert c.level == 0
    # a blocked admission during the skipped ticks is NOT lost: the
    # controller diffs the cumulative counter at the next evaluation
    assert c.observe(4, queue_depth=0, blocked=2, occupancy=0.0) is not None
    assert c.level == 1


def test_reobserving_same_tick_is_noop():
    c = _ctrl()
    assert c.observe(0, **HOT) is not None
    assert c.observe(0, **HOT) is None
    assert c.observe(0, **HOT) is None
    assert (c.level, c.downshifts) == (1, 1)


def test_dwell_blocks_oscillation():
    c = _ctrl(dwell=5)
    sig = [HOT, COOL]
    for t in range(30):                          # adversarial flip-flop load
        c.observe(t, **sig[t % 2])
    ticks = [t for t, _, _ in c.history]
    assert ticks, "controller never swapped under extreme signals"
    assert all(b - a >= 5 for a, b in zip(ticks, ticks[1:]))


def test_monotone_in_queue_depth():
    levels = []
    for depth in range(8):
        c = _ctrl(queue_high=4)
        c.observe(0, queue_depth=depth, blocked=0, occupancy=0.7)
        levels.append(c.level)
    assert levels == sorted(levels)
    assert levels[0] == 0 and levels[-1] == 1


def test_clock_restart_resets_anchors_keeps_level():
    """A new serve() drain restarts the engine step clock at 0; the
    controller must keep serving the level it reached but drop its
    cadence/dwell anchors and the cumulative blocked-counter baseline."""
    c = _ctrl(every=4, dwell=8)
    c.observe(0, queue_depth=0, blocked=0, occupancy=0.0)
    c.observe(4, **HOT)
    assert c.level == 1                          # swap at tick 4
    # clock restart: evaluates immediately (no stale `now - last_eval`
    # wedge), the dwell anchor from tick 4 is dropped, and a cumulative
    # blocked counter *below* the one already seen (fresh Scheduler) must
    # not underflow — it reads as a fresh delta of 1, i.e. hot
    assert c.observe(0, queue_depth=0, blocked=1, occupancy=0.0) is not None
    assert c.level == 2
    assert c._last_eval == 0
    c2 = _ctrl(every=1, dwell=0)
    c2.observe(10, **HOT)
    assert c2.level == 1
    assert c2.observe(0, **COOL) is not None     # restart, then restores
    assert c2.level == 0


def test_plans_memoized_per_level():
    c = _ctrl()
    p1 = c.plan_for(1)
    assert c.plan_for(1) is p1
    assert len(c.bundle.solved) == 1
    c.plan_for(0)
    assert len(c.bundle.solved) == 2
    assert c.bundle.solved[0][0] == pytest.approx(0.02)


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=60)
    @given(st.lists(st.tuples(st.integers(0, 10), st.integers(0, 3),
                              st.floats(0.0, 1.0)),
                    min_size=1, max_size=80))
    def test_controller_never_oscillates_within_dwell(signals):
        """Random load traces: swaps stay >= dwell apart, levels stay in
        range, and every swap moves exactly one ladder level."""
        c = _ctrl(every=2, dwell=5)
        blocked = 0
        for t, (q, dblk, occ) in enumerate(signals):
            blocked += dblk
            c.observe(t, queue_depth=q, blocked=blocked, occupancy=occ)
        ticks = [t for t, _, _ in c.history]
        assert all(b - a >= 5 for a, b in zip(ticks, ticks[1:]))
        prev = 0
        for _, lvl, tau in c.history:
            assert 0 <= lvl < len(c.taus)
            assert abs(lvl - prev) == 1
            assert tau == pytest.approx(c.taus[lvl])
            prev = lvl


# ---------------------------------------------------------------------------
# bundle registry + measured gain tier (real calibration)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def calib():
    m = get_model("llama3_1b", smoke=True, n_layers=2)
    params = m.init(jax.random.key(0))
    batches = [{"tokens": jax.random.randint(jax.random.key(i), (2, 32),
                                             0, 512),
                "labels": jax.random.randint(jax.random.key(i + 50), (2, 32),
                                             0, 512)}
               for i in range(2)]
    bundle = calibrate(m, params, batches,
                       AMPOptions(tau=0.01, objective="ET"))
    return m, params, batches, bundle


def _plans_equal(a: MPPlan, b: MPPlan) -> bool:
    return dataclasses.asdict(a) == dataclasses.asdict(b)


def test_registry_roundtrip_and_freshest_wins(tmp_path, calib):
    m, params, _, bundle = calib
    reg = BundleRegistry(str(tmp_path / "reg"))
    p1 = reg.put(bundle)
    assert os.path.exists(p1)
    arch = bundle.meta["arch"]
    fp = bundle.meta["params_fingerprint"]
    assert arch is not None and fp == _params_fingerprint(params)
    got = reg.find(arch, fp)
    assert _plans_equal(got.solve(tau=0.02), bundle.solve(tau=0.02))
    # second artifact for the same key: the newer mtime wins
    bundle.meta["marker"] = "newer"
    p2 = reg.put(bundle)
    old = os.path.getmtime(p2) - 100
    os.utime(p1, (old, old))
    assert reg.find(arch, fp).meta.get("marker") == "newer"
    ents = reg.entries()
    assert len(ents) == 2                        # two artifacts, one key
    assert {(a, f) for a, f, _ in ents} == {(_safe(arch), _safe(fp))}


def test_registry_rejects_wrong_fingerprint_and_calib_hash(tmp_path, calib):
    _, _, _, bundle = calib
    reg = BundleRegistry(str(tmp_path / "reg"))
    reg.put(bundle)
    arch = bundle.meta["arch"]
    fp = bundle.meta["params_fingerprint"]
    with pytest.raises(LookupError) as ei:
        reg.find(arch, "deadbeef00000000")
    assert _safe(fp) in str(ei.value)            # names what it does hold
    with pytest.raises(LookupError, match="calib_hash"):
        reg.find(arch, fp, calib_hash="0" * 16)
    # matching hash and no-hash both accept
    assert bundle.meta["calib_hash"] is not None
    reg.find(arch, fp, calib_hash=bundle.meta["calib_hash"])
    reg.find(arch, fp, calib_hash=None)


def test_registry_skips_corrupted_bundle(tmp_path, calib, capsys):
    """A truncated/zeroed artifact must not take the registry down: find()
    warns at skip time and falls through to the next-freshest compatible
    bundle; only when nothing valid remains does it raise, naming the
    corrupted files."""
    _, _, _, bundle = calib
    reg = BundleRegistry(str(tmp_path / "reg"))
    p1 = reg.put(bundle)
    p2 = reg.put(bundle)                        # freshest candidate
    old = os.path.getmtime(p2) - 100
    os.utime(p1, (old, old))
    with open(p2, "r+b") as f:                  # truncate mid-archive
        f.truncate(os.path.getsize(p2) // 2)
    arch = bundle.meta["arch"]
    fp = bundle.meta["params_fingerprint"]
    got = reg.find(arch, fp)
    out = capsys.readouterr().out
    assert "skipping corrupted bundle" in out and p2 in out
    assert _plans_equal(got.solve(tau=0.02), bundle.solve(tau=0.02))
    with open(p2, "wb"):                        # zero-byte artifact
        pass
    reg.find(arch, fp)
    with open(p1, "wb"):                        # nothing valid left
        pass
    with pytest.raises(LookupError, match="unreadable"):
        reg.find(arch, fp)


def test_registry_put_requires_identity_meta(tmp_path, calib):
    _, _, _, bundle = calib
    stripped = dataclasses.replace(bundle, meta={})
    with pytest.raises(ValueError):
        BundleRegistry(str(tmp_path / "reg")).put(stripped)


def test_measured_gain_tier_supersedes_roofline(tmp_path, calib):
    _, _, _, bundle = calib
    # work on a private copy: tabulation mutates the bundle in place
    path = str(tmp_path / "b.npz")
    bundle.save(path)
    b = CalibrationBundle.load(path)
    assert b.solve(tau=0.02, objective="ET").meta["gain_tier"] == \
        "roofline_fallback"
    assert b.solve(tau=0.02, objective="TT").meta["gain_tier"] == "analytic"
    key = tabulate_measured_gains(b, lambda assignment: (lambda: None),
                                  objective="ET", n_iters=1, n_warmup=0)
    assert key == "ET_wall" and "ET_wall" in b.objectives
    plan = b.solve(tau=0.02, objective="ET")
    assert plan.meta["gain_tier"] == "measured"
    assert plan.meta["gain_table"] == "ET_wall"
    assert plan.objective == "ET"                # caller-facing name
    # TT keeps pricing from its analytic table
    assert b.solve(tau=0.02, objective="TT").meta["gain_tier"] == "analytic"
    # the measured table survives persistence
    path2 = str(tmp_path / "b2.npz")
    b.save(path2)
    b2 = CalibrationBundle.load(path2)
    assert b2.solve(tau=0.02, objective="ET").meta["gain_tier"] == "measured"
    with pytest.raises(ValueError, match="already a measured tier"):
        tabulate_measured_gains(b2, lambda a: (lambda: None),
                                objective="ET_wall")


# ---------------------------------------------------------------------------
# engine-level swap parity and prefix-index lifecycle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def model():
    return get_model("llama3_1b", smoke=True)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(42)
    return [rng.integers(0, 500, size=12).astype(np.int32) for _ in range(4)]


def _serve(eng, params, prompts, max_new=5, arrivals=None):
    reqs = [Request(rid=i, tokens=p, max_new_tokens=max_new,
                    arrival=0 if arrivals is None else arrivals[i])
            for i, p in enumerate(prompts)]
    return eng.serve(params, reqs)


def test_engine_rejects_mp_plus_adaptive(model):
    ctrl = _ctrl(bundle=FakeBundle(plans={0.01: {}, 0.02: {}, 0.04: {}}))
    with pytest.raises(ValueError, match="not both"):
        ContinuousBatchingEngine(model, mp=MP_ASSIGNMENT, adaptive=ctrl)


def test_never_firing_controller_bit_identical(model, params, prompts):
    """A controller that cannot swap (single-level ladder) must serve
    greedy tokens bit-identical to a plain fixed-plan engine."""
    fixed = ContinuousBatchingEngine(model, n_slots=2, max_len=32,
                                     mp=MP_ASSIGNMENT)
    ref = _serve(fixed, params, prompts)
    ctrl = AdaptiveMPController(
        bundle=FakeBundle(plans={0.01: MP_ASSIGNMENT}), taus=(0.01,),
        every=1, dwell=0, queue_high=1)
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32,
                                   adaptive=ctrl)
    assert eng.mp == MP_ASSIGNMENT               # base plan from level 0
    summ = _serve(eng, params, prompts)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(summ.results[i].tokens,
                                      ref.results[i].tokens)
    c = summ.counters["adaptive"]
    assert c["swaps"] == [] and c["downshifts"] == 0 and c["restores"] == 0
    assert c["final_level"] == 0
    np.testing.assert_allclose(c["taus"], [0.01])


def test_midstream_swap_to_same_plan_bit_identical(model, params, prompts):
    """Two ladder levels solving to the *same* assignment: the swap runs
    the full machinery (step re-memo + prefix invalidation) mid-drain yet
    tokens stay bit-identical to never swapping."""
    fixed = ContinuousBatchingEngine(model, n_slots=2, max_len=32,
                                     mp=MP_ASSIGNMENT)
    arrivals = [0, 0, 4, 4]
    ref = _serve(fixed, params, prompts, arrivals=arrivals)
    ctrl = AdaptiveMPController(
        bundle=FakeBundle(plans={0.01: MP_ASSIGNMENT,
                                 0.02: MP_ASSIGNMENT}),
        taus=(0.01, 0.02), every=1, dwell=2, queue_high=2)
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32,
                                   adaptive=ctrl)
    summ = _serve(eng, params, prompts, arrivals=arrivals)
    c = summ.counters["adaptive"]
    assert c["downshifts"] >= 1, "load never tripped the controller"
    # swaps land at distinct step boundaries, >= dwell apart, in order
    steps = [s["step"] for s in c["swaps"]]
    assert steps == sorted(steps) and len(set(steps)) == len(steps)
    assert all(b - a >= 2 for a, b in zip(steps, steps[1:]))
    assert all(0 <= s < summ.n_steps for s in steps)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(summ.results[i].tokens,
                                      ref.results[i].tokens)


def test_adaptive_downshift_restore_cycle(model, params, prompts):
    """A burst deep enough to trip the high watermark, then a drain long
    enough to cool below the low one: the controller must complete at
    least one downshift->restore cycle and every request must finish."""
    base, aggr = {}, dict(MP_ASSIGNMENT)
    ctrl = AdaptiveMPController(
        bundle=FakeBundle(plans={0.01: base, 0.04: aggr}),
        taus=(0.01, 0.04), every=1, dwell=1, queue_high=3, queue_low=0,
        occ_high=0.9, occ_low=0.5)
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32,
                                   block_size=4, n_blocks=64, adaptive=ctrl)
    ps = prompts * 2                             # 8 requests, 2 slots
    summ = _serve(eng, params, ps, max_new=4)
    c = summ.counters["adaptive"]
    assert c["downshifts"] >= 1 and c["restores"] >= 1
    assert c["final_level"] == 0                 # drained back to base
    assert len(summ.results) == len(ps)
    for i in range(len(ps)):
        assert summ.results[i].tokens.shape[0] == 4   # every token delivered
        assert summ.results[i].first_token_step >= 0
    assert ctrl.level == 0 and not eng.mp        # back on the base (bf16) plan


def test_prefix_index_survives_drains_and_swap_invalidates(model, params,
                                                           prompts):
    """One engine, two drains of the same prompts: the second drain hits
    the prefix index populated by the first and still matches one-shot
    tokens. A plan swap between drains empties the index (quantized K/V
    bytes are plan-dependent), so the next drain rebuilds from scratch."""
    ref = {}
    one = ServeEngine(model, donate=False)
    for i, p in enumerate(prompts):
        r = one.generate(params, {"tokens": jnp.asarray(p)[None]},
                         max_new_tokens=5)
        ref[i] = np.asarray(r.tokens)[0]
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32,
                                   block_size=4, n_blocks=64,
                                   prefix_cache=True)
    s1 = _serve(eng, params, prompts)
    assert s1.counters["prefix_hit_tokens"] == 0
    s2 = _serve(eng, params, prompts)
    assert s2.counters["prefix_hit_requests"] > 0
    assert s2.counters["prefix_hit_tokens"] > 0
    for i in range(len(prompts)):
        np.testing.assert_array_equal(s1.results[i].tokens, ref[i])
        np.testing.assert_array_equal(s2.results[i].tokens, ref[i])
    # swap (even to the same plan) must invalidate the persisted index
    eng._swap_plan(eng.mp)
    s3 = _serve(eng, params, prompts)
    assert s3.counters["prefix_hit_tokens"] == 0
    for i in range(len(prompts)):
        np.testing.assert_array_equal(s3.results[i].tokens, ref[i])
    # and the index repopulates after the invalidation
    s4 = _serve(eng, params, prompts)
    assert s4.counters["prefix_hit_tokens"] > 0


# ---------------------------------------------------------------------------
# scaled fp8 KV: calibration + loss-MSE accuracy gate
# ---------------------------------------------------------------------------


def test_calibrate_kv_scales_shape_and_values(model, params):
    batches = [{"tokens": jax.random.randint(jax.random.key(3), (2, 16),
                                             0, 512)}]
    scales = calibrate_kv_scales(model, params, batches)
    assert len(scales) == model.cfg.n_layers
    for entry in scales:
        assert entry is not None
        names = [n for n, _ in entry]
        assert names == sorted(names) and set(names) == {"k", "v"}
        assert all(s > 0 for _, s in entry)
    # the per-layer tuple drops straight into LMConfig
    cfg = dataclasses.replace(model.cfg, kv_cache_dtype="fp8_e4m3",
                              kv_dequant_scales=scales)
    assert cfg.kv_scales_for(0) == scales[0]


def test_calibrate_kv_scales_rejects_scan(model, params):
    scan = get_model("llama3_1b", smoke=True, scan_layers=True)
    with pytest.raises(ValueError, match="scan"):
        calibrate_kv_scales(scan, scan.init(jax.random.key(0)),
                            [{"tokens": jnp.zeros((1, 8), jnp.int32)}])


def _paged_decode_loss(model, params, toks, label_tok):
    """Per-row decode-step loss through the *paged* read path (dense rings
    ignore dequant scales): prefill fills blocks, one decode step reads
    them back, loss = -log p(label)."""
    ctx = QuantContext()
    B, T = toks.shape
    bs = 4
    caches = model.init_paged_cache(B, 32, bs)
    n_pages = -(-(T + 1) // bs)
    bt = np.asarray([[1 + b * n_pages + pg for pg in range(n_pages)]
                     for b in range(B)], np.int32)
    lens = jnp.full((B,), T, jnp.int32)
    _, caches = model.prefill_chunk(
        params, toks, caches, ctx,
        start_pos=jnp.zeros((B,), jnp.int32), valid_len=lens,
        block_tables=jnp.asarray(bt))
    tok = jnp.full((B, 1), label_tok, jnp.int32)
    logits, _ = model.decode_step(params, tok, lens, caches, ctx,
                                  block_tables=jnp.asarray(bt),
                                  paged_attn="gather")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -np.asarray(logp[:, 0, label_tok], np.float64)


def test_scaled_fp8_kv_accuracy_gate(model, params):
    """The paper's sensitivity metric (loss-MSE vs the bf16-cache
    reference) gates scaled fp8 KV: with V amplitudes pushed past the fp8
    max, the unscaled cache saturates at 448 while calibrated scales map
    the range in-bounds — the scaled loss-MSE must beat unscaled."""
    big = copy.deepcopy(jax.tree_util.tree_map(np.asarray, params))
    for i in range(model.cfg.n_layers):
        node = big["layers"][str(i)]["attn"]["v_proj"]
        node["w"] = np.asarray(node["w"], np.float32) * 400.0
    big = jax.tree_util.tree_map(
        lambda x: jnp.asarray(x, jnp.bfloat16)
        if np.asarray(x).dtype == np.float32 else jnp.asarray(x), big)
    toks = jax.random.randint(jax.random.key(7), (2, 12), 0, 512)

    scales = calibrate_kv_scales(model, big, [{"tokens": toks}])
    v_scales = [dict(e)["v"] for e in scales]
    assert max(v_scales) > 1.0, "amplified V never left the fp8 range"

    def variant(kv_dtype, sc):
        cfg = dataclasses.replace(model.cfg, kv_cache_dtype=kv_dtype,
                                  kv_dequant_scales=sc)
        return type(model)(cfg)

    label = 3
    ref = _paged_decode_loss(variant("bfloat16", None), big, toks, label)
    unscaled = _paged_decode_loss(variant("fp8_e4m3", None), big, toks,
                                  label)
    scaled = _paged_decode_loss(variant("fp8_e4m3", scales), big, toks,
                                label)
    assert np.all(np.isfinite(unscaled)), \
        "unscaled fp8 write must saturate, not NaN-poison the cache"
    mse_unscaled = float(np.mean((unscaled - ref) ** 2))
    mse_scaled = float(np.mean((scaled - ref) ** 2))
    assert mse_scaled < mse_unscaled
    assert np.max(np.abs(scaled - ref)) < np.max(np.abs(unscaled - ref))


def test_mla_nonunit_scales_route_to_gather(model):
    """The fused absorbed-MLA predicate treats non-unit dequant scales as
    a gather condition: a serving engine holding a scaled-fp8 MLA
    checkpoint must drain (fused request silently downgraded), matching
    the explicit gather engine token-for-token."""
    mla = get_model("deepseek_v3_671b", smoke=True, moe_layers=(),
                    mtp_depth=0, mla_absorb_decode=True,
                    kv_cache_dtype="fp8_e4m3",
                    kv_dequant_scales=(("ckv", 0.5), ("kr", 0.5)))
    p = mla.init(jax.random.key(2))
    rng = np.random.default_rng(5)
    ps = [rng.integers(0, 200, size=n).astype(np.int32) for n in (11, 6)]
    outs = {}
    for pa in ("fused", "gather"):
        eng = ContinuousBatchingEngine(mla, n_slots=2, max_len=24,
                                       block_size=4, paged_attn=pa)
        summ = _serve(eng, p, ps, max_new=4)
        outs[pa] = {i: summ.results[i].tokens for i in range(len(ps))}
    for i in range(len(ps)):
        np.testing.assert_array_equal(outs["fused"][i], outs["gather"][i])


# ---------------------------------------------------------------------------
# dense chunked prefill: sliding-window ring widening regression
# ---------------------------------------------------------------------------


def test_dense_chunked_prefill_unaligned_window(model):
    """The documented failing shape: window=12, chunk_len=8, prompt=24.
    The third chunk's window straddles a chunk boundary; an unwidened ring
    (size == window) would have overwritten positions the window still
    needs. Dense chunked tokens must match the one-shot engine."""
    wm = get_model("llama3_1b", smoke=True, sliding_window=12)
    wp = wm.init(jax.random.key(1))
    rng = np.random.default_rng(9)
    ps = [rng.integers(0, 500, size=24).astype(np.int32) for _ in range(2)]
    one = ServeEngine(wm, donate=False)
    ref = {i: np.asarray(one.generate(wp, {"tokens": jnp.asarray(p)[None]},
                                      max_new_tokens=4).tokens)[0]
           for i, p in enumerate(ps)}
    eng = ContinuousBatchingEngine(wm, n_slots=2, max_len=40, paged=False,
                                   chunk_len=8)
    summ = _serve(eng, wp, ps, max_new=4)
    for i in range(len(ps)):
        np.testing.assert_array_equal(summ.results[i].tokens, ref[i])
