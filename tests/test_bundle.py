"""CalibrationBundle / MPPlan artifacts: round-trips, staged-vs-legacy
equality, serve-without-model solves, and cache resumption."""
import dataclasses

import jax
import numpy as np
import pytest

import repro.core.pipeline as pl
from repro.core.mpconfig import MPPlan
from repro.core.pipeline import (AMPOptions, CalibrationBundle,
                                 auto_mixed_precision, calibrate)
from repro.models.registry import get_model


@pytest.fixture(scope="module")
def setup():
    m = get_model("llama3_1b", smoke=True, n_layers=2)
    params = m.init(jax.random.key(0))
    batches = [{"tokens": jax.random.randint(jax.random.key(i), (2, 32), 0, 512),
                "labels": jax.random.randint(jax.random.key(i + 50), (2, 32),
                                             0, 512)}
               for i in range(2)]
    bundle = calibrate(m, params, batches, AMPOptions(tau=0.01, objective="TT"))
    return m, params, batches, bundle


def _plans_equal(a: MPPlan, b: MPPlan) -> bool:
    return dataclasses.asdict(a) == dataclasses.asdict(b)


def test_mpplan_roundtrip_normalizes_tuple_groups(tmp_path, setup):
    """JSON turns tuple groups into lists; a round-tripped plan must still
    compare equal to the in-memory original."""
    _, _, _, bundle = setup
    plan = bundle.solve()
    # force tuple groups on a hand-built plan: __post_init__ normalizes
    tup = MPPlan(assignment=dict(plan.assignment),
                 groups=[tuple(g) for g in plan.groups],
                 objective=plan.objective, tau=plan.tau, budget=plan.budget,
                 predicted_loss_mse=plan.predicted_loss_mse,
                 predicted_gain=plan.predicted_gain, ip_gap=plan.ip_gap,
                 meta=dict(plan.meta))
    assert all(isinstance(g, list) for g in tup.groups)
    assert _plans_equal(tup, plan)
    path = tmp_path / "plan.json"
    plan.save(str(path))
    loaded = MPPlan.load(str(path))
    assert _plans_equal(loaded, plan)
    assert loaded == plan


@pytest.mark.parametrize("ext", ["json", "npz"])
def test_bundle_roundtrip(tmp_path, setup, ext):
    """Saved -> loaded bundle preserves sensitivity, groups, gain tables,
    and solves to the identical plan with no model/params in scope."""
    _, _, _, bundle = setup
    path = tmp_path / f"bundle.{ext}"
    bundle.save(str(path))
    loaded = CalibrationBundle.load(str(path))
    assert loaded.formats == bundle.formats
    assert loaded.ref_format == bundle.ref_format
    assert loaded.sens.sensitivity == bundle.sens.sensitivity
    assert loaded.sens.loss_sq_mean == bundle.sens.loss_sq_mean
    assert loaded.sens.ops == bundle.sens.ops
    assert loaded.meta == bundle.meta
    for obj in bundle.objectives:
        a, b = loaded.objectives[obj], bundle.objectives[obj]
        assert a["groups"] == b["groups"]
        assert all(np.array_equal(x, y) for x, y in zip(a["gains"],
                                                        b["gains"]))
    for objective in ("ET", "TT", "M"):
        for tau in (0.002, 0.02):
            before = bundle.solve(tau=tau, objective=objective)
            after = loaded.solve(tau=tau, objective=objective)
            assert _plans_equal(before, after)
            assert after.meta == before.meta


def test_solve_matches_legacy_auto_mixed_precision(setup):
    """Acceptance: bundle.solve() == legacy auto_mixed_precision() on
    assignment and predicted gain/MSE, for every objective."""
    m, params, batches, bundle = setup
    for objective in ("ET", "TT", "M"):
        for tau in (0.005, 0.05):
            opts = AMPOptions(tau=tau, objective=objective)
            legacy = auto_mixed_precision(m, params, batches, opts,
                                          sens=bundle.sens)
            staged = bundle.solve(tau=tau, objective=objective)
            assert staged.assignment == legacy.assignment
            assert staged.predicted_gain == legacy.predicted_gain
            assert staged.predicted_loss_mse == legacy.predicted_loss_mse
            assert _plans_equal(staged, legacy)


def test_pareto_frontier(setup):
    _, _, _, bundle = setup
    taus = (0.001, 0.01, 0.05)
    plans = bundle.pareto(taus, objective="TT")
    assert [p.tau for p in plans] == list(taus)
    gains = [p.predicted_gain for p in plans]
    assert all(a <= b + 1e-15 for a, b in zip(gains, gains[1:]))
    for p in plans:
        assert p.predicted_loss_mse <= p.budget * (1 + 1e-9)


def test_solve_defaults_and_unknown_objective(setup):
    _, _, _, bundle = setup
    plan = bundle.solve()
    assert plan.tau == bundle.default_tau
    assert plan.objective == bundle.default_objective
    with pytest.raises(KeyError):
        bundle.solve(objective="WALLCLOCK")


def test_unknown_ops(setup):
    _, _, _, bundle = setup
    names = bundle.op_names
    assert bundle.unknown_ops(names) == set()
    missing = bundle.unknown_ops(names[1:])
    assert missing == {names[0]}


def test_calibrate_cache_resumes_without_recalibration(tmp_path, setup,
                                                       monkeypatch):
    """A matching cached bundle short-circuits calibration entirely; a
    params change invalidates it via the fingerprint."""
    m, params, batches, _ = setup
    path = tmp_path / "cache.npz"
    opts = AMPOptions(tau=0.01, objective="TT")
    calls = {"n": 0}
    orig = pl.calibrate_sensitivity

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(pl, "calibrate_sensitivity", counting)
    first = calibrate(m, params, batches, opts, cache=str(path))
    assert calls["n"] == 1 and path.exists()
    second = calibrate(m, params, batches, opts, cache=str(path))
    assert calls["n"] == 1  # pure cache hit
    assert _plans_equal(second.solve(), first.solve())
    # different params -> fingerprint mismatch -> recalibrate
    params2 = jax.tree.map(lambda x: x * 1.5, params)
    calibrate(m, params2, batches, opts, cache=str(path))
    assert calls["n"] == 2


def test_calibrate_cache_rejects_different_gain_model(tmp_path, setup):
    """Cached tables must come from the same gain-model type: a bundle of
    roofline ET tables cannot satisfy a request for another ET model."""
    from repro.core.timegain import TheoreticalGainModel
    from repro.hw.profiles import TPU_V5E
    m, params, batches, _ = setup
    path = tmp_path / "cache.json"
    opts = AMPOptions(tau=0.01, objective="ET")
    calibrate(m, params, batches, opts, cache=str(path))  # roofline ET
    swapped = calibrate(m, params, batches, opts,
                        gain_models={"ET": TheoreticalGainModel(TPU_V5E)},
                        cache=str(path))
    assert swapped.meta["gain_models"] == {"ET": "TheoreticalGainModel"}


def test_calibrate_cache_rejects_option_mismatch(tmp_path, setup):
    m, params, batches, _ = setup
    path = tmp_path / "cache.json"
    calibrate(m, params, batches, AMPOptions(max_group_size=8),
              cache=str(path))
    narrower = calibrate(m, params, batches, AMPOptions(max_group_size=2),
                         cache=str(path))
    assert narrower.meta["max_group_size"] == 2
    assert all(len(g) <= 2
               for g in narrower.objectives["ET"]["groups"])


def test_corrupt_cache_falls_back_to_calibration(tmp_path, setup):
    m, params, batches, bundle = setup
    path = tmp_path / "cache.json"
    path.write_text("{not json")
    rebuilt = calibrate(m, params, batches,
                        AMPOptions(tau=0.01, objective="TT"),
                        cache=str(path))
    assert _plans_equal(rebuilt.solve(), bundle.solve())
    # and the bad file was replaced with a loadable artifact
    assert CalibrationBundle.load(str(path)).solve() is not None
