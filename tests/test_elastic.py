"""Elastic rescale: checkpoints restore onto a different mesh (subprocess
with 8 placeholder devices — the device count must be set pre-jax-init)."""
import os
import subprocess
import sys
import textwrap


def test_restore_onto_different_mesh(tmp_path):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.distributed import sharding as shd
        from repro.models.registry import get_model
        from repro.nn.spec import flatten_paths

        m = get_model("llama3_1b", smoke=True)
        params = m.init(jax.random.key(0))

        # save from a (2, 2) mesh
        mesh_a = jax.make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
        sh_a = shd.param_shardings(m.param_specs(), mesh_a)
        flat = flatten_paths(params)
        placed = {{p: jax.device_put(v, sh_a[p]) for p, v in flat.items()}}
        cm = CheckpointManager(r"{tmp_path}")
        from repro.nn.spec import tree_from_flat
        cm.save(7, {{"params": tree_from_flat(placed)}})

        # restore onto a (4, 2) mesh — elastic rescale
        mesh_b = jax.make_mesh((4, 2), ("data", "model"),
                               devices=jax.devices()[:8])
        sh_b = shd.param_shardings(m.param_specs(), mesh_b)
        shardings = {{f"params/{{k}}": s for k, s in sh_b.items()}}
        step, tree, _ = cm.restore(shardings=shardings)
        assert step == 7
        for p, v in flatten_paths(tree["params"]).items():
            np.testing.assert_array_equal(
                np.asarray(v, np.float32), np.asarray(flat[p], np.float32))
            assert v.sharding.mesh.shape["data"] == 4
        print("ELASTIC-OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], env=env, cwd=".",
                         capture_output=True, text=True, timeout=300)
    assert "ELASTIC-OK" in out.stdout, out.stderr[-2000:]
