"""Fault-tolerant serving: the injection harness, engine containment
(bounded retry through the bit-exact resume path, poisoned-page quarantine,
fused->gather degradation), the tau-anchored numerical guardrail, pool book
reconciliation, and the corrupted-bundle registry fall-through.

The load-bearing bar throughout: a drain with injected faults always
terminates with a result for every request, every fault-unaffected request's
tokens are bit-identical to a fault-free run, and a retried request that
completes is bit-identical too (resume is bit-exact). ``failed`` requests
keep the last-known-good prefix."""
import os

import jax
import numpy as np
import pytest

from repro.core.mpconfig import MPPlan
from repro.models.registry import get_model
from repro.serve import (AdaptiveMPController, ContinuousBatchingEngine,
                         FaultInjector, FaultSpec, NumericalGuardrail,
                         PagedCachePool, Request)

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False

MP_ASSIGNMENT = {
    "layers/0/attn/q_proj": "fp8_e4m3",
    "layers/1/mlp/down_proj": "fp8_e4m3",
    "lm_head": "fp8_e4m3",
}


@pytest.fixture(scope="module")
def model():
    return get_model("llama3_1b", smoke=True)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(42)
    # two shared-prefix pairs so prefix caching + COW sharing is live in
    # every engine-level fault test
    fam = rng.integers(0, 500, size=8).astype(np.int32)
    out = [np.concatenate([fam, rng.integers(0, 500, 4).astype(np.int32)])
           for _ in range(2)]
    out += [rng.integers(0, 500, size=12).astype(np.int32) for _ in range(2)]
    return out


def _requests(prompts, max_new=6, **kw):
    return [Request(rid=i, tokens=p, max_new_tokens=max_new, **kw)
            for i, p in enumerate(prompts)]


@pytest.fixture(scope="module")
def reference(model, params, prompts):
    """Fault-free continuous-batching tokens (the bit-exactness bar)."""
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32)
    summ = eng.serve(params, _requests(prompts))
    assert all(r.status == "ok" for r in summ.results.values())
    return {i: np.asarray(r.tokens) for i, r in summ.results.items()}


def _assert_contained(summ, ref, *, allow=("ok", "retried", "failed")):
    """Every request has a terminal result; ok/retried are bit-identical to
    the fault-free run; failed keep a bit-exact last-known-good prefix."""
    assert set(summ.results) == set(ref)
    for i, r in summ.results.items():
        assert r.status in allow, (i, r.status)
        if r.status in ("ok", "retried"):
            np.testing.assert_array_equal(r.tokens, ref[i])
        else:
            np.testing.assert_array_equal(
                np.asarray(r.tokens), ref[i][:len(r.tokens)])


# ---------------------------------------------------------------------------
# the fault matrix: every fault class x sync/async, paged + prefix sharing
# ---------------------------------------------------------------------------

MATRIX = [
    ("step_exception", dict(step=2, phase="decode")),
    ("step_exception", dict(step=0, phase="prefill")),
    ("nan_page", dict(step=2, slot=0, page=0)),
    ("nan_logits", dict(step=2, slot=1)),
    ("alloc_failure", dict(step=1, slot=2)),
    ("consumer_error", dict(step=2, slot=3)),
    ("consumer_stall", dict(step=2, hang_s=0.001)),
    ("hung_step", dict(step=2, phase="decode", hang_s=0.001)),
]


@pytest.mark.parametrize("sync", [False, True])
@pytest.mark.parametrize("kind,kw", MATRIX,
                         ids=[f"{k}-{kw.get('phase', 'any')}"
                              for k, kw in MATRIX])
def test_fault_matrix(model, params, prompts, reference, kind, kw, sync):
    inj = FaultInjector([FaultSpec(kind=kind, **kw)])
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32, faults=inj)
    summ = eng.serve(params, _requests(prompts), sync=sync)
    _assert_contained(summ, reference)
    f = summ.counters["faults"]
    assert f["injected"].get(kind) == 1, f
    if kind in ("nan_page", "nan_logits"):
        # the tripwire caught the poison and the pages left circulation
        assert f["seen"].get("nonfinite_logits", 0) >= 1
        assert f["quarantined_blocks"] >= 1
        assert any(r.status == "retried" for r in summ.results.values())
    if kind == "consumer_error":
        # contained per-request, no retry (the tokens already streamed)
        assert sum(1 for r in summ.results.values()
                   if r.status == "failed") == 1
    if kind in ("consumer_stall", "hung_step"):
        # pure latency faults: nothing degrades to failed/retried
        assert all(r.status == "ok" for r in summ.results.values())
    # pool books settle after every containment path
    pool = eng._pool
    assert pool.check_consistency()["ok"]
    assert pool.blocks_in_use == 0 and pool._reserved == 0


def test_retry_budget_exhausted_fails(model, params, prompts, reference):
    """max_retries=0: the poisoned request retires ``failed`` with its
    last-known-good prefix; everyone else is untouched."""
    inj = FaultInjector([FaultSpec("nan_logits", step=3, slot=1)])
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32, faults=inj,
                                   max_retries=0)
    summ = eng.serve(params, _requests(prompts))
    _assert_contained(summ, reference)
    failed = [r for r in summ.results.values() if r.status == "failed"]
    assert len(failed) == 1 and len(failed[0].tokens) < len(
        reference[failed[0].rid])
    assert summ.counters["faults"]["failed"] == 1


def test_repeated_kernel_faults_degrade_to_gather(model, params, prompts,
                                                  reference):
    """Past kernel_fault_limit step faults the engine swaps fused paged
    attention for the gather path mid-drain — a dispatch switch, and the
    pinned fused/gather parity keeps tokens bit-identical."""
    inj = FaultInjector([FaultSpec("step_exception", step=1),
                         FaultSpec("step_exception", step=3)])
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32, faults=inj,
                                   max_retries=3, kernel_fault_limit=2)
    summ = eng.serve(params, _requests(prompts))
    _assert_contained(summ, reference)
    f = summ.counters["faults"]
    assert f["kernel_faults"] == 2 and f["degraded_paged_attn"]
    assert eng.paged_attn == "gather"
    assert all(r.status == "retried" for r in summ.results.values())


def test_impossible_after_quarantine_fails_gracefully(model, params):
    """Quarantine shrinks capacity below a previously-admissible request's
    worst-case need: it retires ``failed`` instead of crashing the drain."""
    prompt = np.random.default_rng(7).integers(0, 500, 12).astype(np.int32)
    inj = FaultInjector([FaultSpec("nan_logits", step=1, slot=0)])
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32, faults=inj,
                                   block_size=4, n_blocks=7, max_retries=2)
    summ = eng.serve(params, [Request(rid=0, tokens=prompt,
                                      max_new_tokens=8)])
    r = summ.results[0]
    assert r.status == "failed"
    f = summ.counters["faults"]
    assert f["quarantined_blocks"] >= 4
    assert f["seen"].get("impossible_request", 0) == 1
    # the pool stays consistent with pages permanently out of circulation
    pool = eng._pool
    assert pool.check_consistency()["ok"]
    assert pool.n_quarantined_blocks == f["quarantined_blocks"]
    assert pool.allocatable_blocks == 6 - pool.n_quarantined_blocks


# ---------------------------------------------------------------------------
# pool-level quarantine mechanics
# ---------------------------------------------------------------------------


def test_quarantine_slot_removes_blocks_for_good(model):
    pool = PagedCachePool(model, n_slots=2, max_len=32, block_size=4,
                          n_blocks=12)
    p = np.random.default_rng(0).integers(0, 500, 12).astype(np.int32)
    s = pool.alloc_slot(12, 4, digests=pool.prefix_digests(p))
    pool.ensure_range(s, 0, 12)
    pool.register_prefix(s, 12)
    owned = [int(b) for b in pool.block_tables[s] if b >= 0]
    n = pool.quarantine_slot(s)
    pool.free_slot(s)
    assert n == len(owned) == pool.n_quarantined_blocks
    assert pool.quarantined_blocks == n
    assert pool.check_consistency()["ok"]
    # quarantined pages never reappear: not free, not cached, not indexed
    assert not set(owned) & set(pool._free_blocks_by_shard[0])
    assert not set(owned) & set(pool._cached_by_shard[0])
    assert pool.allocatable_blocks == (pool.blocks_per_shard - 1 - n)
    # a prefix that previously hit now misses (the chain was de-indexed)
    s2 = pool.alloc_slot(12, 4, digests=pool.prefix_digests(p))
    assert pool.matched_tokens(s2) == 0
    pool.ensure_range(s2, 0, 12)
    assert not set(owned) & {int(b) for b in pool.block_tables[s2] if b >= 0}
    pool.free_slot(s2)
    assert pool.blocks_in_use == 0 and pool._reserved == 0


def test_quarantine_forks_live_borrowers(model):
    """A borrower of a shared (prefix-hit) block keeps decoding: quarantine
    COW-forks the page away before pulling it from circulation."""
    pool = PagedCachePool(model, n_slots=2, max_len=32, block_size=4,
                          n_blocks=16)
    p = np.random.default_rng(1).integers(0, 500, 12).astype(np.int32)
    s0 = pool.alloc_slot(12, 4, digests=pool.prefix_digests(p))
    pool.ensure_range(s0, 0, 12)
    pool.register_prefix(s0, 12)
    s1 = pool.alloc_slot(12, 4, digests=pool.prefix_digests(p))
    hit = pool.matched_tokens(s1)
    assert hit >= 8                             # >= two full pages borrowed
    pool.ensure_range(s1, hit, 12)              # COW-forks any partial page
    shared = (set(int(b) for b in pool.block_tables[s0] if b >= 0)
              & set(int(b) for b in pool.block_tables[s1] if b >= 0))
    assert len(shared) >= 2                     # fully-shared prefix pages
    n = pool.quarantine_slot(s0)
    pool.free_slot(s0)
    assert n >= 3
    after = [int(b) for b in pool.block_tables[s1] if b >= 0]
    assert not set(after) & shared              # every page forked away
    assert pool.check_consistency()["ok"]
    # the borrower still decodes into its (now private) pages
    for pos in range(12, 15):
        pool.ensure_block(s1, pos)
    pool.free_slot(s1)
    assert pool.blocks_in_use == 0 and pool._reserved == 0


def test_poison_block_is_device_visible(model):
    pool = PagedCachePool(model, n_slots=1, max_len=16, block_size=4,
                          n_blocks=6)
    s = pool.alloc_slot(8, 2)
    pool.ensure_range(s, 0, 8)
    blk = int(pool.block_tables[s][1])
    pool.poison_block(blk)
    import jax.numpy as jnp
    leaves = jax.tree_util.tree_leaves(pool.caches)
    hit = [x for x in leaves
           if x.ndim >= 2 and x.shape[0] == pool.n_blocks
           and jnp.issubdtype(x.dtype, jnp.floating)]
    assert hit
    for x in hit:
        host = np.asarray(x[blk], np.float32)
        assert np.isnan(host).all()
        other = int(pool.block_tables[s][0])
        assert np.isfinite(np.asarray(x[other], np.float32)).all()


def test_reconcile_settles_cooked_books(model):
    pool = PagedCachePool(model, n_slots=2, max_len=32, block_size=4,
                          n_blocks=12)
    s = pool.alloc_slot(12, 2)
    pool.ensure_range(s, 0, 12)
    blk = int(pool.block_tables[s][0])
    pool._ref[blk] += 2                         # cook the refcount
    orphan = pool._free_blocks_by_shard[0].pop()
    pool._ref[orphan] = 1                       # strand a block
    assert not pool.check_consistency()["ok"]
    rep = pool.reconcile()
    assert rep["ref_fixed"] >= 1 and rep["orphans_rerouted"] >= 1
    assert rep["consistent"] and pool.check_consistency()["ok"]
    pool.free_slot(s)
    assert pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# injector construction
# ---------------------------------------------------------------------------


def test_injector_parse_and_random():
    inj = FaultInjector.parse(
        "nan_page@step=3,slot=0,page=1;alloc_failure@step=5,slot=2;"
        "hung_step@step=1,phase=prefill,hang_s=0.5")
    kinds = [s.kind for s in inj.specs]
    assert kinds == ["nan_page", "alloc_failure", "hung_step"]
    assert inj.specs[0].page == 1 and inj.specs[1].slot == 2
    assert inj.specs[2].phase == "prefill"
    assert inj.specs[2].hang_s == pytest.approx(0.5)
    with pytest.raises(ValueError):
        FaultInjector.parse("bogus_kind@step=1")
    with pytest.raises(ValueError):
        FaultInjector.parse("")
    a = FaultInjector.random(11, 6, max_step=10)
    b = FaultInjector.random(11, 6, max_step=10)
    assert [vars(x) for x in a.specs] == [vars(y) for y in b.specs]
    assert [vars(x) for x in FaultInjector.random(12, 6).specs] != \
        [vars(y) for y in a.specs]


def test_injector_hooks_fire_once_and_respect_clock():
    inj = FaultInjector([FaultSpec("step_exception", step=4),
                         FaultSpec("alloc_failure", step=2, slot=1)])
    inj.tick(0)
    assert inj.on_step("decode") is None        # not armed yet
    inj.on_alloc(1)
    inj.tick(3)
    inj.on_alloc(0)                             # wrong slot: no fire
    with pytest.raises(Exception, match="allocation failure"):
        inj.on_alloc(1)
    inj.tick(5)
    with pytest.raises(Exception, match="step exception"):
        inj.on_step("decode")
    assert inj.on_step("decode") is None        # fired exactly once
    assert inj.exhausted and inj.fired == {"step_exception": 1,
                                           "alloc_failure": 1}


# ---------------------------------------------------------------------------
# tau-anchored numerical guardrail
# ---------------------------------------------------------------------------


def _plan(assignment, budget, tau=0.01):
    return MPPlan(assignment=dict(assignment), groups=[list(assignment)],
                  objective="ET", tau=tau, budget=budget,
                  predicted_loss_mse=budget, predicted_gain=1.0)


def test_guardrail_unit_semantics():
    g = NumericalGuardrail(every=4, margin=2.0, max_breaches=2)
    assert not g.observe_mse(0, 1.0, None)      # no budget: record only
    assert g.checks == 1 and g.last_mse == 1.0
    assert not g.observe_mse(4, float("nan"), 1e-6)   # NaN never breaches
    assert g.breaches == 0
    assert not g.observe_mse(8, 1.0, 1e-6)      # breach 1 of 2
    assert g.observe_mse(12, 1.0, 1e-6)         # breach 2: restore now
    assert g.restored_at == 12
    assert not g.observe_mse(16, 1.0, 1e-6)     # restores only once
    assert g.budget_for(_plan(MP_ASSIGNMENT, 0.5)) == pytest.approx(0.5)
    assert g.budget_for(object()) is None
    explicit = NumericalGuardrail(budget=0.25)
    assert explicit.budget_for(_plan(MP_ASSIGNMENT, 0.5)) == \
        pytest.approx(0.25)
    with pytest.raises(ValueError):
        NumericalGuardrail(every=0)


def test_force_restore_bypasses_dwell():
    class _Stub:
        def solve(self, tau, objective):
            return _plan(MP_ASSIGNMENT if tau > 0.01 else {}, 1e-4, tau)

    c = AdaptiveMPController(bundle=_Stub(), taus=[0.01, 0.04],
                             every=4, dwell=100)
    c.level = 1
    plan = c.force_restore(7)
    assert c.level == 0 and plan.tau == pytest.approx(0.01)
    assert c.guardrail_restores == 1 and c.restores == 1
    assert c.history[-1][0] == 7
    n_hist = len(c.history)
    c.force_restore(8)                          # idempotent at level 0
    assert c.level == 0 and c.guardrail_restores == 2
    assert len(c.history) == n_hist


def test_guardrail_breach_restores_mid_drain(model, params, prompts,
                                             reference):
    """A plan whose solved budget lies about its real loss-MSE trips the
    shadow check; the engine force-restores to the base plan mid-drain and
    requests admitted after the restore match the base-plan reference."""
    lying = _plan(MP_ASSIGNMENT, budget=1e-14, tau=1e-7)
    grail = NumericalGuardrail(every=2, margin=2.0)
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32, mp=lying,
                                   guardrail=grail)
    summ = eng.serve(params, _requests(prompts))
    g = summ.counters["guardrail"]
    assert g["breaches"] >= 1 and g["restored_at"] is not None
    assert g["swaps"] and g["swaps"][0]["mse"] > g["swaps"][0]["budget"]
    assert eng.mp is None                       # restored to the base plan
    # post-restore drain on the same engine is bit-identical to fault-free
    summ2 = eng.serve(params, _requests(prompts))
    for i, r in summ2.results.items():
        np.testing.assert_array_equal(r.tokens, reference[i])
    # and the restored engine stops paying for shadow steps
    assert summ2.counters["guardrail"]["checks"] == g["checks"]


def test_guardrail_controller_restore(model, params, prompts):
    """With an adaptive controller attached, a breach routes through
    force_restore: the ladder jumps to level 0 regardless of dwell."""
    class _Stub:
        def solve(self, tau, objective):
            return _plan({} if tau <= 0.01 else MP_ASSIGNMENT,
                         budget=1e-14 if tau > 0.01 else 1e-4, tau=tau)

    ctrl = AdaptiveMPController(bundle=_Stub(), taus=[0.01, 0.04],
                                every=1000, dwell=1000)
    ctrl.level = 1                              # start on the lying plan
    grail = NumericalGuardrail(every=2, margin=2.0)
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32,
                                   adaptive=ctrl, guardrail=grail)
    summ = eng.serve(params, _requests(prompts))
    assert ctrl.level == 0 and ctrl.guardrail_restores == 1
    assert summ.counters["guardrail"]["breaches"] >= 1
    assert all(r.status == "ok" for r in summ.results.values())


def test_honest_plan_never_breaches(model, params, prompts):
    honest = _plan(MP_ASSIGNMENT, budget=1e6)
    grail = NumericalGuardrail(every=3)
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32, mp=honest,
                                   guardrail=grail)
    eng.serve(params, _requests(prompts))
    assert grail.checks >= 1 and grail.breaches == 0
    assert grail.last_mse is not None and np.isfinite(grail.last_mse)


# ---------------------------------------------------------------------------
# property test: random fault schedules x random request mixes
# ---------------------------------------------------------------------------


def _check_random_faults(model, params, seed):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, 500, size=int(rng.integers(6, 14)))
               .astype(np.int32) for _ in range(int(rng.integers(3, 6)))]
    reqs = [Request(rid=i, tokens=p,
                    max_new_tokens=int(rng.integers(2, 7)),
                    arrival=int(rng.integers(0, 6)))
            for i, p in enumerate(prompts)]
    clean = ContinuousBatchingEngine(model, n_slots=3, max_len=32).serve(
        params, list(reqs))
    ref = {i: np.asarray(r.tokens) for i, r in clean.results.items()}
    inj = FaultInjector.random(seed, int(rng.integers(1, 5)),
                               max_step=12, n_slots=3, max_pages=3)
    for sp in inj.specs:
        sp.hang_s = 0.001
    eng = ContinuousBatchingEngine(model, n_slots=3, max_len=32, faults=inj,
                                   max_retries=2)
    summ = eng.serve(params, list(reqs))
    _assert_contained(summ, ref)
    pool = eng._pool
    assert pool.check_consistency()["ok"]
    assert pool.blocks_in_use == 0 and pool._reserved == 0


def test_random_fault_schedules_fixed_seeds(model, params):
    for seed in (0, 1, 2, 3, 4, 5):
        _check_random_faults(model, params, seed)


if HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_random_fault_schedules(seed):
        m = get_model("llama3_1b", smoke=True)
        _check_random_faults(m, m.init(jax.random.key(0)), seed)
