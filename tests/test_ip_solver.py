"""IP solver (MCKP, eq. 5): optimality vs brute force on random instances.

``hypothesis`` is optional (CI installs it; minimal images may not): the
property tests run only when it imports, and deterministic seed sweeps below
exercise the same checks regardless, so this module always collects and
covers ``solve_mckp``.
"""
import numpy as np
import pytest

from repro.core.ip_solver import MCKPGroup, pareto_prune, solve_mckp

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False


def _random_instance(rng, n_groups, n_cfg):
    groups = []
    for j in range(n_groups):
        c = rng.uniform(0, 10, n_cfg)
        d = rng.uniform(0, 5, n_cfg)
        # ensure a zero-cost option exists (the all-BF16 config)
        d[0], c[0] = 0.0, 0.0
        groups.append(MCKPGroup(f"g{j}", list(range(n_cfg)), c, d))
    return groups


def _check_dp_and_greedy_match_brute(seed, n_groups, n_cfg, budget):
    rng = np.random.default_rng(seed)
    groups = _random_instance(rng, n_groups, n_cfg)
    exact = solve_mckp(groups, budget, method="brute")
    heur = solve_mckp(groups, budget, method="dp", bins=20000)
    assert heur.d_total <= budget * (1 + 1e-9) + 1e-12
    # dp on a fine grid should be within a hair of optimal, never above
    assert heur.c_total <= exact.c_total + 1e-9
    assert heur.c_total >= exact.c_total * 0.99 - 1e-6
    # the LP bound is a true upper bound
    assert exact.upper_bound >= exact.c_total - 1e-9


def _check_pareto_prune_preserves_optimum(seed):
    rng = np.random.default_rng(seed)
    groups = _random_instance(rng, 3, 6)
    budget = float(rng.uniform(0, 10))
    full = solve_mckp(groups, budget, method="brute")
    pruned_groups = []
    for g in groups:
        kept, c, d = pareto_prune(g)
        pruned_groups.append(MCKPGroup(g.name, [g.labels[i] for i in kept], c, d))
    pr = solve_mckp(pruned_groups, budget, method="brute")
    assert np.isclose(pr.c_total, full.c_total)


# ---------------------------------------------------------------------------
# deterministic sweeps (always run, with or without hypothesis)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed,n_groups,n_cfg,budget", [
    (0, 1, 1, 0.0), (1, 1, 6, 20.0), (2, 3, 3, 5.0), (3, 5, 4, 0.5),
    (4, 4, 2, 12.0), (5, 2, 5, 3.3), (6, 5, 6, 8.0), (7, 3, 6, 0.01),
])
def test_dp_and_greedy_match_brute_cases(seed, n_groups, n_cfg, budget):
    _check_dp_and_greedy_match_brute(seed, n_groups, n_cfg, budget)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 8, 13, 21])
def test_pareto_prune_preserves_optimum_cases(seed):
    _check_pareto_prune_preserves_optimum(seed)


def test_infeasible_raises():
    g = MCKPGroup("g", [0, 1], np.array([1.0, 2.0]), np.array([5.0, 6.0]))
    with pytest.raises(ValueError):
        solve_mckp([g], budget=1.0, method="brute")


def test_monotone_in_budget():
    rng = np.random.default_rng(7)
    groups = _random_instance(rng, 4, 4)
    prev = -1.0
    for b in (0.0, 1.0, 3.0, 10.0, 100.0):
        r = solve_mckp(groups, b, method="brute")
        assert r.c_total >= prev - 1e-12
        prev = r.c_total


def test_large_instance_runs_fast():
    rng = np.random.default_rng(3)
    groups = _random_instance(rng, 300, 4)   # ~4^300 brute-force impossible
    r = solve_mckp(groups, budget=50.0, method="auto", bins=4096)
    assert r.method in ("dp", "lp_greedy")
    assert r.d_total <= 50.0 * (1 + 1e-9)
    assert r.gap < 0.05  # certified near-optimal via the LP bound


# ---------------------------------------------------------------------------
# property tests (hypothesis only)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10**6), st.integers(1, 5), st.integers(1, 6),
           st.floats(0.0, 20.0))
    def test_dp_and_greedy_match_brute(seed, n_groups, n_cfg, budget):
        _check_dp_and_greedy_match_brute(seed, n_groups, n_cfg, budget)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 10**6))
    def test_pareto_prune_preserves_optimum(seed):
        _check_pareto_prune_preserves_optimum(seed)
