"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fp8_matmul, mp_flash_attention, ops, quantize_fp8, ref
from repro.kernels.quant_cast import amax, scale_cast


@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 128, 128, 128, 256),
    (384, 256, 256, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float8_e4m3fn, jnp.float8_e5m2])
def test_fp8_matmul_allclose(M, K, N, bm, bn, bk, dtype, rng):
    x = jax.random.normal(rng, (M, K), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(rng, 1), (N, K), jnp.float32)
    mx = 448.0 if dtype == jnp.float8_e4m3fn else 57344.0
    sx = mx / jnp.max(jnp.abs(x))
    sw = mx / jnp.max(jnp.abs(w))
    xq = (x * sx).astype(dtype)
    wq = (w * sw).astype(dtype)
    y = fp8_matmul(xq, wq, 1 / sx, 1 / sw, block_m=bm, block_n=bn, block_k=bk,
                   interpret=True)
    want = ref.fp8_matmul_ref(xq, wq, 1 / sx, 1 / sw)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("shape", [(128, 64), (256, 384), (512, 96)])
def test_amax_and_cast(shape, rng):
    x = jax.random.normal(rng, shape, jnp.float32) * 7
    a = amax(x, block_m=128, interpret=True)
    np.testing.assert_allclose(float(a), float(ref.amax_ref(x)), rtol=1e-6)
    s = 448.0 / a
    xq = scale_cast(x, s, block_m=128, interpret=True)
    want = ref.scale_cast_ref(x, s)
    np.testing.assert_array_equal(np.asarray(xq, np.float32),
                                  np.asarray(want, np.float32))


def test_quantize_fp8_roundtrip(rng):
    x = jax.random.normal(rng, (256, 128), jnp.float32)
    xq, s_inv = quantize_fp8(x, 448.0, jnp.float8_e4m3fn, interpret=True)
    back = np.asarray(xq, np.float32) * float(s_inv)
    rel = np.abs(back - np.asarray(x)) / (np.abs(np.asarray(x)) + 1e-6)
    assert np.percentile(rel, 99) < 0.07


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("T,S,D,bq,bk", [(128, 128, 64, 64, 64),
                                         (256, 256, 32, 128, 64)])
def test_mp_flash_attention_bf16(causal, T, S, D, bq, bk, rng):
    B, H = 2, 3
    q = jax.random.normal(rng, (B, H, T, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, H, S, D), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, H, S, D), jnp.float32)
    o = mp_flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                           interpret=True)
    want = ref.mp_flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-3)


def test_mp_flash_attention_fp8_quantized(rng):
    """FP8 q/k/v + in-kernel prob quantization vs the identical-semantics
    oracle (exact match of the quantization points, not just 'close')."""
    B, H, T, D = 1, 2, 128, 64
    keys = [jax.random.fold_in(rng, i) for i in range(3)]
    q = jax.random.normal(keys[0], (B, H, T, D), jnp.float32)
    k = jax.random.normal(keys[1], (B, H, T, D), jnp.float32)
    v = jax.random.normal(keys[2], (B, H, T, D), jnp.float32)
    qq, sq = quantize_fp8(q.reshape(-1, D), 448.0, jnp.float8_e4m3fn, interpret=True)
    kq, sk = quantize_fp8(k.reshape(-1, D), 448.0, jnp.float8_e4m3fn, interpret=True)
    vq, sv = quantize_fp8(v.reshape(-1, D), 448.0, jnp.float8_e4m3fn, interpret=True)
    qq, kq, vq = (a.reshape(B, H, T, D) for a in (qq, kq, vq))
    o = mp_flash_attention(qq, kq, vq, sq, sk, sv, causal=True, block_q=64,
                           block_k=64, quant_probs=True, interpret=True)
    want = ref.mp_flash_attention_ref(qq, kq, vq, sq, sk, sv, causal=True,
                                      quant_probs=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=2e-2)


def test_fp8_linear_wrapper_pads_odd_shapes(rng):
    x = jax.random.normal(rng, (100, 200), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(rng, 5), (72, 200), jnp.bfloat16)
    y = ops.fp8_linear(x, w, interpret=True)
    assert y.shape == (100, 72)
    want = x.astype(jnp.float32) @ w.astype(jnp.float32).T
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32) - want))
                / jnp.max(jnp.abs(want)))
    assert rel < 0.12


def test_qops_pallas_impl_path(rng):
    """qops.linear with impl='pallas' routes 2D matmuls through the kernel."""
    from repro.quant.qops import QuantContext, linear
    x = jax.random.normal(rng, (64, 128), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(rng, 2), (32, 128), jnp.bfloat16)
    ctx = QuantContext(mode="mp", mp={"op": "fp8_e4m3"}, impl="pallas")
    y = linear(ctx, "op", x, w)
    plain = linear(QuantContext(), "op", x, w)
    rel = float(jnp.max(jnp.abs(y.astype(jnp.float32)
                                - plain.astype(jnp.float32)))
                / (float(jnp.max(jnp.abs(plain.astype(jnp.float32)))) + 1e-6))
    assert rel < 0.2
