"""Per-architecture smoke tests (reduced configs, CPU): forward/train step
runs, output shapes correct, no NaNs; serve-path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.encdec import EncDec
from repro.models.registry import ARCH_IDS, build_model, get_model, get_smoke_config
from repro.quant.qops import QuantContext

CTX = QuantContext()


def _batch_for(m, key, B=2, S=32):
    if isinstance(m, EncDec):
        return {"frames": jax.random.normal(key, (B, S, m.cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(key, (B, S), 0, m.cfg.vocab_size),
                "labels": jax.random.randint(key, (B, S), 0, m.cfg.vocab_size)}
    batch = {"tokens": jax.random.randint(key, (B, S), 0, m.cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, m.cfg.vocab_size)}
    if m.cfg.prefix_embed:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, 8, m.cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_loss_and_grad(arch, rng):
    m = get_model(arch, smoke=True)
    params = m.init(rng)
    batch = _batch_for(m, jax.random.fold_in(rng, 3))
    loss, grads = jax.value_and_grad(
        lambda p: m.loss(p, batch, CTX))(params)
    assert jnp.isfinite(loss), arch
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ["llama3_1b", "qwen2p5_3b", "mamba2_370m",
                                  "hymba_1p5b", "deepseek_v3_671b",
                                  "moonshot_v1_16b_a3b"])
def test_prefill_decode_matches_full_forward(arch, rng):
    m = get_model(arch, smoke=True)
    params = m.init(rng)
    B, T = 2, 12
    toks = jax.random.randint(jax.random.fold_in(rng, 7), (B, T), 0, 256)
    full = m.apply(params, toks, CTX).astype(jnp.float32)
    caches = m.init_cache(B, 16)
    lp, caches = m.prefill(params, toks[:, :6], caches, CTX)
    errs = [float(jnp.max(jnp.abs(lp[:, 0].astype(jnp.float32) - full[:, 5])))]
    for t in range(6, T):
        lg, caches = m.decode_step(params, toks[:, t:t + 1],
                                   jnp.array(t, jnp.int32), caches, CTX)
        if t < T - 1:
            errs.append(float(jnp.max(jnp.abs(
                lg[:, 0].astype(jnp.float32) - full[:, t]))))
    assert max(errs) < 0.05, (arch, errs)


def test_whisper_prefill_decode(rng):
    m = get_model("whisper_base", smoke=True)
    params = m.init(rng)
    B, S = 2, 16
    frames = jax.random.normal(rng, (B, S, m.cfg.d_model), jnp.bfloat16)
    toks = jax.random.randint(rng, (B, 10), 0, 256)
    full = m.apply(params, {"frames": frames, "tokens": toks}, CTX)
    caches = m.init_cache(B, 16, S)
    lp, caches = m.prefill(params, frames, toks[:, :5], caches, CTX)
    err = float(jnp.max(jnp.abs(lp[:, 0].astype(jnp.float32)
                                - full[:, 4].astype(jnp.float32))))
    assert err < 0.05
    for t in range(5, 9):
        lg, caches = m.decode_step(params, toks[:, t:t + 1],
                                   jnp.array(t, jnp.int32), caches, CTX)
        err = float(jnp.max(jnp.abs(lg[:, 0].astype(jnp.float32)
                                    - full[:, t].astype(jnp.float32))))
        assert err < 0.05, t


def test_sliding_window_ring_buffer(rng):
    """Decode with a ring buffer (W < T) matches full attention restricted
    to the window."""
    m = get_model("hymba_1p5b", smoke=True, n_layers=2,
                  block_types=("hybrid",) * 2, sliding_window=8,
                  global_attn_layers=())
    params = m.init(rng)
    B, T = 1, 20
    toks = jax.random.randint(rng, (B, T), 0, 256)
    full = m.apply(params, toks, CTX).astype(jnp.float32)
    caches = m.init_cache(B, 8)  # ring buffer of exactly the window
    lp, caches = m.prefill(params, toks[:, :10], caches, CTX)
    for t in range(10, T):
        lg, caches = m.decode_step(params, toks[:, t:t + 1],
                                   jnp.array(t, jnp.int32), caches, CTX)
        if t < T - 1:
            err = float(jnp.max(jnp.abs(lg[:, 0].astype(jnp.float32)
                                        - full[:, t])))
            assert err < 0.06, (t, err)


def test_scan_layers_equivalence(rng):
    from repro.nn.spec import flatten_paths, tree_from_flat
    cfg_u = get_smoke_config("qwen2p5_3b", n_layers=4)
    cfg_s = get_smoke_config("qwen2p5_3b", n_layers=4, scan_layers=True)
    mu, ms = build_model(cfg_u), build_model(cfg_s)
    pu = mu.init(rng)
    flat_u = flatten_paths(pu)
    flat_s = {}
    for path, spec in ms.param_specs().items():
        if path.startswith("segments/"):
            sub = "/".join(path.split("/")[2:])
            flat_s[path] = jnp.stack(
                [flat_u[f"layers/{i}/{sub}"] for i in range(4)])
        else:
            flat_s[path] = flat_u[path]
    ps = tree_from_flat(flat_s)
    toks = jax.random.randint(rng, (2, 16), 0, 256)
    lu = mu.apply(pu, toks, CTX).astype(jnp.float32)
    ls = ms.apply(ps, toks, CTX).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(lu - ls))) < 0.05


def test_flash_matches_reference(rng):
    cfg_ref = get_smoke_config("llama3_8b", n_layers=2)           # no flash
    cfg_fl = get_smoke_config("llama3_8b", n_layers=2, flash_min_seq=16,
                              flash_block=16)
    m_ref, m_fl = build_model(cfg_ref), build_model(cfg_fl)
    params = m_ref.init(rng)
    toks = jax.random.randint(rng, (2, 64), 0, 256)
    a = m_ref.apply(params, toks, CTX).astype(jnp.float32)
    b = m_fl.apply(params, toks, CTX).astype(jnp.float32)
    assert float(jnp.max(jnp.abs(a - b))) < 0.12  # bf16 accumulation order


def test_full_configs_instantiate_abstractly():
    """FULL-size configs build specs + abstract params w/o allocation."""
    from repro.analysis.model_stats import param_stats
    expectations = {"deepseek_v3_671b": (600e9, 750e9),
                    "qwen2p5_32b": (30e9, 36e9),
                    "mamba2_370m": (0.3e9, 0.45e9),
                    "hymba_1p5b": (1.2e9, 2.0e9)}
    for arch, (lo, hi) in expectations.items():
        m = get_model(arch)
        n = param_stats(m)["total"]
        assert lo < n < hi, (arch, n)


def test_mla_absorbed_decode_matches_expanded(rng):
    """Latent-space (absorbed) MLA decode == expanded decode (bf16 tol)."""
    outs = {}
    for absorb in (False, True):
        # dense-MLA variant: MoE top-k routing flips on bf16 noise would
        # otherwise amplify tiny attention-path differences into logits
        m = get_model("deepseek_v3_671b", smoke=True, moe_layers=(),
                      mla_absorb_decode=absorb)
        p = m.init(rng)
        toks = jax.random.randint(jax.random.fold_in(rng, 11), (2, 10), 0, 256)
        caches = m.init_cache(2, 12)
        lp, caches = m.prefill(p, toks[:, :5], caches, CTX)
        logs = []
        for t in range(5, 10):
            lg, caches = m.decode_step(p, toks[:, t:t + 1],
                                       jnp.array(t, jnp.int32), caches, CTX)
            logs.append(np.asarray(lg[:, 0], np.float32))
        outs[absorb] = np.stack(logs)
    np.testing.assert_allclose(outs[False], outs[True], atol=0.08)
