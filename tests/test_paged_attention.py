"""Fused paged-attention decode kernel vs the gather reference.

The masking invariant under test: the kernel must never *use* a key past a
row's logical length — dead block-table entries (unallocated -1 or stale ids
left by freed slots) and the garbage tail of the last live block must not
leak into the output. Stale-referenced blocks are poisoned with huge finite
garbage for cross-path comparisons (and with NaN for the kernel-only
never-fetched test), so any out-of-length read that survives masking throws
the comparison far outside tolerance.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_attention
from repro.nn import layers as L
from repro.quant.qops import QuantContext

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False


def _random_paged_case(seed, *, B, n_pages, bs, dtype, vacant_row=True,
                       stale_entries=True):
    """Cache + block tables with every hazard the pool can produce: rows of
    different lengths, unallocated (-1) entries, stale entries pointing at
    NaN-poisoned blocks, and a vacant row (all -1, length 1 — the engine's
    garbage-row shape, which must read only the trash block 0)."""
    rng = np.random.default_rng(seed)
    live_budget = B * n_pages
    n_blocks = 1 + live_budget + 4          # trash + live + 4 poison blocks
    lengths = rng.integers(1, n_pages * bs + 1, size=B).astype(np.int32)
    if vacant_row:
        lengths[-1] = 1
    perm = rng.permutation(np.arange(1, 1 + live_budget))
    poison = np.arange(1 + live_budget, n_blocks)
    tables = np.full((B, n_pages), -1, np.int32)
    c = 0
    for b in range(B):
        if vacant_row and b == B - 1:
            continue                         # vacant: all entries stay -1
        for pg in range(-(-int(lengths[b]) // bs)):
            tables[b, pg] = perm[c]
            c += 1
        if stale_entries:                    # dead entries may be stale ids
            for pg in range(-(-int(lengths[b]) // bs), n_pages):
                if rng.random() < 0.5:
                    tables[b, pg] = rng.choice(poison)
    return n_blocks, jnp.asarray(tables), jnp.asarray(lengths), poison, rng


POISON = 224.0   # huge-but-finite garbage, inside the fp8_e4m3 range: the
# gather reference multiplies exactly-zero probs into gathered stale blocks
# (0 * NaN would be NaN there), so cross-path comparisons need finite poison;
# test_kernel_ignores_nan_in_unreferenced_blocks asserts the kernel's
# stronger never-fetches-them property with real NaN.


def _fill(rng, shape, dtype, poison_blocks, value=POISON):
    x = rng.normal(size=shape).astype(np.float32)
    if len(poison_blocks):
        x[np.asarray(poison_blocks, np.int64)] = value
    return jnp.asarray(x).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float8_e4m3fn])
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("seed", [0, 1])
def test_kernel_matches_gather_reference(dtype, window, seed):
    B, Hkv, G, Dk = 3, 2, 2, 32
    n_pages, bs = 5, 4
    n_blocks, bt, lengths, poison, rng = _random_paged_case(
        seed, B=B, n_pages=n_pages, bs=bs, dtype=dtype)
    k = _fill(rng, (n_blocks, bs, Hkv, Dk), dtype, poison)
    v = _fill(rng, (n_blocks, bs, Hkv, Dk), dtype, poison)
    q = jnp.asarray(rng.normal(size=(B, Hkv, G, Dk)), jnp.bfloat16)
    kw = dict(window=window, scale=math.sqrt(Dk), scale_mode="div",
              score_dtype=jnp.bfloat16, probs_dtype=jnp.bfloat16,
              out_dtype=jnp.bfloat16)
    got = paged_decode_attention(q, k, v, bt, lengths, interpret=True, **kw)
    want = ref.paged_decode_attention_ref(q, k, v, bt, lengths, **kw)
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    assert np.all(np.isfinite(got)), "stale/dead entries leaked into output"
    # f32-summation-order tolerance only: a masking leak shows up as NaN or
    # a wildly wrong row, not a sub-percent wiggle (bitwise parity against
    # the in-repo gather path is asserted in test_layer_fused_matches_gather)
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-5)


def test_kernel_ignores_nan_in_unreferenced_blocks():
    """The kernel must never *fetch* a block that only stale/dead table
    entries point at: with NaN in those blocks, its output is finite and
    bit-identical to the same case with them zeroed. (The gather reference
    cannot pass this — it materializes every table slot and 0 * NaN = NaN —
    which is exactly the hazard the in-kernel clamp removes.)"""
    dtype = jnp.bfloat16
    B, Hkv, G, Dk, n_pages, bs = 3, 2, 2, 32, 5, 4
    n_blocks, bt, lengths, poison, rng = _random_paged_case(
        2, B=B, n_pages=n_pages, bs=bs, dtype=dtype)
    kw = dict(scale=math.sqrt(Dk), scale_mode="div",
              score_dtype=jnp.bfloat16, probs_dtype=jnp.bfloat16,
              out_dtype=jnp.bfloat16)

    def run(poison_value):
        r = np.random.default_rng(99)
        k = _fill(r, (n_blocks, bs, Hkv, Dk), dtype, poison, poison_value)
        v = _fill(r, (n_blocks, bs, Hkv, Dk), dtype, poison, poison_value)
        q = jnp.asarray(r.normal(size=(B, Hkv, G, Dk)), jnp.bfloat16)
        return np.asarray(paged_decode_attention(
            q, k, v, bt, lengths, interpret=True, **kw), np.float32)

    with_nan = run(np.nan)
    assert np.all(np.isfinite(with_nan)), "kernel fetched a stale/dead block"
    np.testing.assert_array_equal(with_nan, run(0.0))


def test_kernel_never_reads_past_length_exact_boundary():
    """Length exactly at a page boundary, mid-page, and 1: the first masked
    position sits in a NaN-free block's garbage tail as well as in poisoned
    stale blocks — output must equal a reference computed from a cache whose
    out-of-length entries were overwritten with a *different* value."""
    B, Hkv, G, Dk, n_pages, bs = 3, 1, 2, 16, 4, 4
    rng = np.random.default_rng(3)
    n_blocks = 1 + B * n_pages
    lengths = jnp.asarray([8, 5, 1], jnp.int32)    # boundary, mid-page, min
    bt = np.full((B, n_pages), -1, np.int32)
    ids = iter(range(1, n_blocks))
    for b in range(B):
        for pg in range(-(-int(lengths[b]) // bs)):
            bt[b, pg] = next(ids)
    bt = jnp.asarray(bt)
    k = rng.normal(size=(n_blocks, bs, Hkv, Dk)).astype(np.float32)
    v = rng.normal(size=(n_blocks, bs, Hkv, Dk)).astype(np.float32)
    q = jnp.asarray(rng.normal(size=(B, Hkv, G, Dk)), jnp.bfloat16)
    kw = dict(scale=math.sqrt(Dk), scale_mode="div",
              score_dtype=jnp.bfloat16, probs_dtype=jnp.bfloat16,
              out_dtype=jnp.bfloat16)

    def run(karr, varr):
        return np.asarray(paged_decode_attention(
            q, jnp.asarray(karr, jnp.bfloat16), jnp.asarray(varr,
            jnp.bfloat16), bt, lengths, interpret=True, **kw), np.float32)

    base = run(k, v)
    k2, v2 = k.copy(), v.copy()
    for b in range(B):                       # scribble every dead position
        for pos in range(int(lengths[b]), n_pages * bs):
            pg, off = divmod(pos, bs)
            blk = int(bt[b, pg])
            if blk >= 0:
                k2[blk, off] = 1e4
                v2[blk, off] = -1e4
    np.testing.assert_array_equal(base, run(k2, v2))


def test_kernel_mla_shape_and_scales():
    """MLA-absorbed shape: Hkv=1, H query heads, rope second operand,
    v = k (latent), multiplied scale, f32 all the way. Plus the fp8
    per-tensor dequant scales path (k_scale/v_scale != 1)."""
    B, H, r, dr = 2, 4, 24, 8
    n_pages, bs = 4, 4
    n_blocks, bt, lengths, poison, rng = _random_paged_case(
        7, B=B, n_pages=n_pages, bs=bs, dtype=jnp.bfloat16)
    ckv = _fill(rng, (n_blocks, bs, 1, r), jnp.bfloat16, poison)
    kr = _fill(rng, (n_blocks, bs, 1, dr), jnp.bfloat16, poison)
    q1 = jnp.asarray(rng.normal(size=(B, 1, H, r)), jnp.float32)
    q2 = jnp.asarray(rng.normal(size=(B, 1, H, dr)), jnp.float32)
    kw = dict(q2=q2, k2=kr, scale=1.0 / math.sqrt(r + dr), scale_mode="mul",
              out_dtype=jnp.float32)
    got = paged_decode_attention(q1, ckv, None, bt, lengths, interpret=True,
                                 **kw)
    want = ref.paged_decode_attention_ref(q1, ckv, None, bt, lengths, **kw)
    got = np.asarray(got, np.float32)
    assert np.all(np.isfinite(got))
    np.testing.assert_allclose(got, np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-6)

    # fp8 cache with real per-tensor dequant scales
    kq = _fill(rng, (16, bs, 1, r), jnp.float8_e4m3fn, [])
    btq = jnp.asarray(np.arange(1, 1 + B * n_pages).reshape(B, n_pages))
    ln = jnp.asarray([n_pages * bs, 3], jnp.int32)
    kw = dict(scale=math.sqrt(r), scale_mode="div", k_scale=0.25,
              v_scale=2.0, out_dtype=jnp.float32)
    got = paged_decode_attention(q1, kq, None, btq, ln, interpret=True, **kw)
    want = ref.paged_decode_attention_ref(q1, kq, None, btq, ln, **kw)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-4, atol=1e-6)


if HAS_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 10_000), st.integers(1, 4), st.integers(2, 6),
           st.sampled_from([2, 4, 8]), st.one_of(st.none(),
                                                 st.integers(1, 24)))
    def test_kernel_matches_reference_property(seed, B, n_pages, bs, window):
        Hkv, G, Dk = 2, 2, 16
        n_blocks, bt, lengths, poison, rng = _random_paged_case(
            seed, B=B, n_pages=n_pages, bs=bs, dtype=jnp.bfloat16)
        k = _fill(rng, (n_blocks, bs, Hkv, Dk), jnp.bfloat16, poison)
        v = _fill(rng, (n_blocks, bs, Hkv, Dk), jnp.bfloat16, poison)
        q = jnp.asarray(rng.normal(size=(B, Hkv, G, Dk)), jnp.bfloat16)
        kw = dict(window=window, scale=math.sqrt(Dk), scale_mode="div",
                  score_dtype=jnp.bfloat16, probs_dtype=jnp.bfloat16,
                  out_dtype=jnp.bfloat16)
        got = np.asarray(paged_decode_attention(q, k, v, bt, lengths,
                                                interpret=True, **kw),
                         np.float32)
        assert np.all(np.isfinite(got))
        np.testing.assert_allclose(
            got, np.asarray(ref.paged_decode_attention_ref(
                q, k, v, bt, lengths, **kw), np.float32),
            rtol=1e-2, atol=1e-5)


# ---------------------------------------------------------------------------
# layer-level dispatch: the kernel switch lives in use_fused_paged
# ---------------------------------------------------------------------------


def _layer_attention_case(paged_attn, ctx=None, window=None, kv_scales=None):
    cfg = L.AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
                       window=window, kv_dequant_scales=kv_scales)
    rng = np.random.default_rng(11)
    specs = L.attn_specs("attn", cfg)
    key = jax.random.key(0)
    params = {}
    for path, ps in specs.items():
        key, sub = jax.random.split(key)
        node = params
        parts = path.split("/")[1:]
        for q in parts[:-1]:
            node = node.setdefault(q, {})
        node[parts[-1]] = (jax.random.normal(sub, ps.shape, jnp.float32)
                           * 0.05).astype(jnp.bfloat16)
    B, bs, n_pages = 2, 4, 4
    n_blocks = 1 + B * n_pages
    cache = {"k": jnp.asarray(rng.normal(size=(n_blocks, bs, 2, 16)),
                              jnp.bfloat16),
             "v": jnp.asarray(rng.normal(size=(n_blocks, bs, 2, 16)),
                              jnp.bfloat16)}
    bt = jnp.asarray(np.arange(1, 1 + B * n_pages).reshape(B, n_pages))
    x = jnp.asarray(rng.normal(size=(B, 1, 64)), jnp.bfloat16)
    positions = jnp.asarray([[9], [4]], jnp.int32)
    ctx = ctx or QuantContext()
    y, new_cache = L.attention(params, ctx, "attn", cfg, x, positions,
                               cache=cache, cache_pos=positions[:, 0],
                               block_tables=bt, paged_attn=paged_attn)
    return np.asarray(y, np.float32), new_cache


@pytest.mark.parametrize("window", [None, 6])
def test_layer_fused_matches_gather(window):
    yf, cf = _layer_attention_case("fused", window=window)
    yg, cg = _layer_attention_case("gather", window=window)
    np.testing.assert_array_equal(yf, yg)
    for name in ("k", "v"):                  # identical cache writes too
        np.testing.assert_array_equal(np.asarray(cf[name], np.float32),
                                      np.asarray(cg[name], np.float32))


def test_scan_mode_traced_window_fused_decode():
    """Scan-mode segments feed the kernel a *traced* per-layer window (a
    scanned-over int32 mixing the real window with the BIG_WINDOW sentinel
    for global layers): fused and gather decode must still agree bitwise."""
    from repro.models.registry import get_model
    model = get_model("qwen2p5_3b", smoke=True, n_layers=2, scan_layers=True,
                      sliding_window=6, global_attn_layers=(1,))
    params = model.init(jax.random.key(0))
    ctx = QuantContext()
    rng = np.random.default_rng(31)
    B, bs, nb = 2, 4, 16
    caches = model.init_paged_cache(B, nb, bs)
    bt = np.full((B, 4), -1, np.int32)
    ids = iter(range(1, nb))
    lens = [9, 5]
    for b in range(B):
        for pg in range(-(-lens[b] // bs)):
            bt[b, pg] = next(ids)
    toks = jnp.asarray(rng.integers(0, 200, (B, 12)), jnp.int32)
    _, caches = model.prefill_chunk(
        params, toks, caches, ctx, start_pos=jnp.zeros((B,), jnp.int32),
        valid_len=jnp.asarray(lens, jnp.int32), block_tables=jnp.asarray(bt))
    for b in range(B):
        pg = lens[b] // bs
        if bt[b, pg] < 0:
            bt[b, pg] = next(ids)
    tok = jnp.asarray(rng.integers(0, 200, (B, 1)), jnp.int32)
    outs = {}
    for pa in ("fused", "gather"):
        lg, _ = model.decode_step(params, tok, jnp.asarray(lens, jnp.int32),
                                  caches, ctx, block_tables=jnp.asarray(bt),
                                  paged_attn=pa)
        outs[pa] = np.asarray(lg, np.float32)
    np.testing.assert_array_equal(outs["fused"], outs["gather"])


def test_fused_dispatch_predicate():
    """The single switch: MP formats on the attention BGEMMs, probe mode,
    and registry traces all force the gather path."""
    ctx = QuantContext()
    assert L.use_fused_paged(ctx, "layers/0/attn", "fused")
    assert not L.use_fused_paged(ctx, "layers/0/attn", "gather")
    mp_ctx = QuantContext(mode="mp",
                          mp={"layers/0/attn/qk_matmul": "fp8_e4m3"})
    assert not L.use_fused_paged(mp_ctx, "layers/0/attn", "fused")
    assert L.use_fused_paged(mp_ctx, "layers/1/attn", "fused")
    mp_ctx2 = QuantContext(mode="mp",
                           mp={"layers/0/attn/av_matmul": "fp8_e5m2"})
    assert not L.use_fused_paged(mp_ctx2, "layers/0/attn", "fused")
    assert not L.use_fused_paged(QuantContext(mode="probe"), "x", "fused")
    assert not L.use_fused_paged(QuantContext(registry=[]), "x", "fused")
    with pytest.raises(AssertionError):
        L.use_fused_paged(ctx, "x", "flash")


def test_layer_mp_on_bgemm_falls_back_to_gather():
    """A layer whose qk_matmul carries an MP format must produce the exact
    quantized reference output even when paged_attn='fused' is requested."""
    mp = {"attn/qk_matmul": "fp8_e4m3"}
    ctx_mp = QuantContext(mode="mp", mp=mp, act_scale_token=True)
    yf, _ = _layer_attention_case("fused", ctx=ctx_mp)
    yg, _ = _layer_attention_case("gather", ctx=ctx_mp)
    np.testing.assert_array_equal(yf, yg)


# ---------------------------------------------------------------------------
# KV dequant scales: one mapping, both read paths
# ---------------------------------------------------------------------------


def test_layer_scaled_kv_fused_matches_gather():
    """Non-unit ``kv_dequant_scales`` must dequantize identically on both
    paged read paths (regression: the gather fallback used to drop them,
    silently diverging from the fused kernel's in-register dequant)."""
    scales = (("k", 0.5), ("v", 2.0))
    yf, _ = _layer_attention_case("fused", kv_scales=scales)
    yg, _ = _layer_attention_case("gather", kv_scales=scales)
    np.testing.assert_array_equal(yf, yg)
    # and the scales actually bite — a unit-scale run differs
    yu, _ = _layer_attention_case("gather")
    assert not np.array_equal(yg, yu)


def test_paged_gather_applies_dequant_scales():
    """layers.paged_gather with scales == the kernel oracle's gathered
    dequant (f32 multiply then cast), exercised through fp8 storage where
    the rounding point actually matters; absent/unit entries stay a plain
    upcast bit-identical to the legacy gather."""
    rng = np.random.default_rng(5)
    cache = {"k": jnp.asarray(rng.normal(size=(7, 4, 2, 8)),
                              jnp.float8_e4m3fn),
             "v": jnp.asarray(rng.normal(size=(7, 4, 2, 8)),
                              jnp.float8_e4m3fn)}
    bt = jnp.asarray([[1, 3, -1], [2, 6, 4]], jnp.int32)
    g, _ = L.paged_gather(cache, bt, jnp.bfloat16,
                          {"k": 0.5, "v": 2.0})
    for name, s in (("k", 0.5), ("v", 2.0)):
        want = ref._paged_deq(cache[name], bt, jnp.bfloat16, s)
        np.testing.assert_array_equal(np.asarray(g[name], np.float32),
                                      np.asarray(want, np.float32))
    g1, _ = L.paged_gather(cache, bt, jnp.bfloat16, {"k": 1.0})
    legacy, _ = L.paged_gather(cache, bt, jnp.bfloat16)
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(g1[name], np.float32),
                                      np.asarray(legacy[name], np.float32))


def test_mla_fused_rejects_nonunit_scales():
    """The fused absorbed-MLA path cannot reproduce the gather path's bf16
    rounding of scaled latents, so it must refuse non-unit scales instead
    of silently diverging."""
    cfg = L.MLAConfig(d_model=32, n_heads=2, q_lora_rank=8, kv_lora_rank=8,
                      qk_nope_dim=8, qk_rope_dim=4, v_head_dim=8,
                      absorb_decode=True)
    rng = np.random.default_rng(7)
    B, bs, n_pages = 1, 4, 2
    p = {"kv_b_proj": {"w": jnp.asarray(
        rng.normal(size=(2 * (8 + 8), 8)) * 0.05, jnp.bfloat16)}}
    qn = jnp.asarray(rng.normal(size=(B, 1, 2, 8)), jnp.bfloat16)
    qr = jnp.asarray(rng.normal(size=(B, 1, 2, 4)), jnp.bfloat16)
    cache = {"ckv": jnp.asarray(rng.normal(size=(5, bs, 8)), jnp.bfloat16),
             "kr": jnp.asarray(rng.normal(size=(5, bs, 4)), jnp.bfloat16)}
    bt = jnp.asarray([[1, 2]], jnp.int32)
    pos = jnp.asarray([[5]], jnp.int32)
    with pytest.raises(ValueError, match="non-unit"):
        L._mla_decode_absorbed_paged(p, QuantContext(), "mla", cfg, qn, qr,
                                     cache, bt, pos,
                                     scales={"ckv": 0.5, "kr": 0.5})
