"""Partition (Alg. 2): paper Fig. 6 structure + invariants on random DAGs.

``hypothesis`` is optional: property tests run when it is installed, and a
deterministic random-DAG sweep checks the same invariants without it.
"""
import numpy as np
import pytest

from repro.core.graphs import build_graph
from repro.core.partition import GraphSpec, partition_sequential
from repro.models.registry import get_model

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False


def test_llama_block_matches_paper_fig6():
    """One llama layer must split into V1={q,k,v,qk,av}, V2={o},
    V3={gate,up}, V4={down} (+ lm_head as its own group)."""
    m = get_model("llama3_1b", smoke=True, n_layers=1)
    groups = partition_sequential(build_graph(m))
    flat = [set(g) for g in groups]
    assert {"layers/0/attn/q_proj", "layers/0/attn/k_proj",
            "layers/0/attn/v_proj", "layers/0/attn/qk_matmul",
            "layers/0/attn/av_matmul"} in flat
    assert {"layers/0/attn/o_proj"} in flat
    assert {"layers/0/mlp/gate_proj", "layers/0/mlp/up_proj"} in flat
    assert {"layers/0/mlp/down_proj"} in flat
    assert {"lm_head"} in flat
    assert len(groups) == 5


@pytest.mark.parametrize("arch,n", [("mamba2_370m", None), ("hymba_1p5b", None),
                                    ("moonshot_v1_16b_a3b", 2),
                                    ("deepseek_v3_671b", None),
                                    ("whisper_base", None)])
def test_partition_covers_all_quantizable(arch, n):
    kw = {"n_layers": n} if n else {}
    m = get_model(arch, smoke=True, **kw)
    g = build_graph(m)
    groups = partition_sequential(g)
    names = [x for grp in groups for x in grp]
    assert sorted(names) == sorted(g.quantizable_nodes())
    assert len(names) == len(set(names))


def test_keep_residual_merges_block():
    """With residual edges kept, a block collapses into one big group."""
    m = get_model("llama3_1b", smoke=True, n_layers=1)
    g = build_graph(m)
    merged = partition_sequential(g, drop_residual=False)
    split = partition_sequential(g, drop_residual=True)
    assert len(merged) < len(split)


def test_max_group_size_split():
    m = get_model("llama3_1b", smoke=True, n_layers=1)
    groups = partition_sequential(build_graph(m), max_group_size=2)
    assert all(len(g) <= 2 for g in groups)


# ---------------------------------------------------------------------------
# invariants on random layered DAGs
# ---------------------------------------------------------------------------


def _check_partition_invariants(g, max_group_size=None):
    groups = partition_sequential(g, max_group_size=max_group_size)
    names = [x for grp in groups for x in grp]
    # groups form a partition of the quantizable ops: coverage + uniqueness
    assert sorted(names) == sorted(g.quantizable_nodes())
    assert len(names) == len(set(names))
    assert all(grp for grp in groups)        # no empty groups
    if max_group_size is not None:
        assert all(len(grp) <= max_group_size for grp in groups)
    # order-preserving: no edge from a later group back into an earlier one
    order = {n: i for i, grp in enumerate(groups) for n in grp}
    for (a, b) in g.edges:
        if a in order and b in order:
            assert order[a] <= order[b]


def _layered_dag(int_fn, bool_fn, subset_fn, pick_fn) -> GraphSpec:
    """Random layered single-sink DAG, generator-agnostic.

    ``int_fn(lo, hi)`` -> int in [lo, hi]; ``bool_fn()`` -> bool;
    ``subset_fn(seq)`` -> non-empty unique subset; ``pick_fn(seq)`` -> one
    element. Both the numpy and the hypothesis sweeps build through this,
    so they always test the same DAG family.
    """
    n_ranks = int_fn(2, 6)
    widths = [int_fn(1, 4) for _ in range(n_ranks)]
    g = GraphSpec()
    ranks = []
    idx = 0
    for w in widths:
        rank = []
        for _ in range(w):
            name = f"n{idx}"
            g.add(name, quantizable=bool_fn())
            rank.append(name)
            idx += 1
        ranks.append(rank)
    # connect each node to >=1 node in the next rank (guarantees single flow)
    for a, b in zip(ranks, ranks[1:]):
        for u in a:
            for v in subset_fn(b):
                g.edge(u, v)
        for v in b:  # every node needs a predecessor
            if not any((u, v) in g.edges for u in a):
                g.edge(pick_fn(a), v)
    # funnel all sinks into one terminal vertex (paper: single-sink DAG)
    g.add("sink")
    nxt = g.successors(False)
    for nname in list(g.nodes):
        if nname != "sink" and not nxt[nname]:
            g.edge(nname, "sink")
    return g


def _numpy_random_dag(rng) -> GraphSpec:
    return _layered_dag(
        int_fn=lambda lo, hi: int(rng.integers(lo, hi + 1)),
        bool_fn=lambda: bool(rng.integers(0, 2)),
        subset_fn=lambda seq: [str(v) for v in rng.choice(
            seq, size=int(rng.integers(1, len(seq) + 1)), replace=False)],
        pick_fn=lambda seq: str(rng.choice(seq)))


@pytest.mark.parametrize("seed", range(20))
def test_partition_invariants_cases(seed):
    g = _numpy_random_dag(np.random.default_rng(seed))
    _check_partition_invariants(g)


@pytest.mark.parametrize("seed,cap", [(0, 1), (1, 2), (2, 3), (3, 2), (4, 1)])
def test_partition_invariants_max_group_size_cases(seed, cap):
    g = _numpy_random_dag(np.random.default_rng(seed))
    _check_partition_invariants(g, max_group_size=cap)


# ---------------------------------------------------------------------------
# property tests (hypothesis only)
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:
    @st.composite
    def random_dag(draw):
        return _layered_dag(
            int_fn=lambda lo, hi: draw(st.integers(lo, hi)),
            bool_fn=lambda: draw(st.booleans()),
            subset_fn=lambda seq: draw(st.lists(
                st.sampled_from(seq), min_size=1, max_size=len(seq),
                unique=True)),
            pick_fn=lambda seq: draw(st.sampled_from(seq)))

    @settings(max_examples=40, deadline=None)
    @given(random_dag())
    def test_partition_invariants(g):
        _check_partition_invariants(g)

    @settings(max_examples=20, deadline=None)
    @given(random_dag(), st.integers(1, 3))
    def test_partition_invariants_max_group_size(g, cap):
        _check_partition_invariants(g, max_group_size=cap)
