"""Partition (Alg. 2): paper Fig. 6 structure + invariants on random DAGs."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.graphs import build_graph
from repro.core.partition import GraphSpec, partition_sequential
from repro.models.registry import get_model


def test_llama_block_matches_paper_fig6():
    """One llama layer must split into V1={q,k,v,qk,av}, V2={o},
    V3={gate,up}, V4={down} (+ lm_head as its own group)."""
    m = get_model("llama3_1b", smoke=True, n_layers=1)
    groups = partition_sequential(build_graph(m))
    assert groups[0] == sorted(
        ["layers/0/attn/q_proj", "layers/0/attn/k_proj", "layers/0/attn/v_proj",
         "layers/0/attn/qk_matmul", "layers/0/attn/av_matmul"],
        key=lambda n: ("qk" in n) + 2 * ("av" in n))[:5] or True
    flat = [set(g) for g in groups]
    assert {"layers/0/attn/q_proj", "layers/0/attn/k_proj",
            "layers/0/attn/v_proj", "layers/0/attn/qk_matmul",
            "layers/0/attn/av_matmul"} in flat
    assert {"layers/0/attn/o_proj"} in flat
    assert {"layers/0/mlp/gate_proj", "layers/0/mlp/up_proj"} in flat
    assert {"layers/0/mlp/down_proj"} in flat
    assert {"lm_head"} in flat
    assert len(groups) == 5


@pytest.mark.parametrize("arch,n", [("mamba2_370m", None), ("hymba_1p5b", None),
                                    ("moonshot_v1_16b_a3b", 2),
                                    ("deepseek_v3_671b", None),
                                    ("whisper_base", None)])
def test_partition_covers_all_quantizable(arch, n):
    kw = {"n_layers": n} if n else {}
    m = get_model(arch, smoke=True, **kw)
    g = build_graph(m)
    groups = partition_sequential(g)
    names = [x for grp in groups for x in grp]
    assert sorted(names) == sorted(g.quantizable_nodes())
    assert len(names) == len(set(names))


def test_keep_residual_merges_block():
    """With residual edges kept, a block collapses into one big group."""
    m = get_model("llama3_1b", smoke=True, n_layers=1)
    g = build_graph(m)
    merged = partition_sequential(g, drop_residual=False)
    split = partition_sequential(g, drop_residual=True)
    assert len(merged) < len(split)


def test_max_group_size_split():
    m = get_model("llama3_1b", smoke=True, n_layers=1)
    groups = partition_sequential(build_graph(m), max_group_size=2)
    assert all(len(g) <= 2 for g in groups)


# ---------------------------------------------------------------------------
# property tests on random layered DAGs
# ---------------------------------------------------------------------------

@st.composite
def random_dag(draw):
    n_ranks = draw(st.integers(2, 6))
    widths = [draw(st.integers(1, 4)) for _ in range(n_ranks)]
    g = GraphSpec()
    ranks = []
    idx = 0
    for w in widths:
        rank = []
        for _ in range(w):
            name = f"n{idx}"
            g.add(name, quantizable=draw(st.booleans()))
            rank.append(name)
            idx += 1
        ranks.append(rank)
    # connect each node to >=1 node in the next rank (guarantees single flow)
    for a, b in zip(ranks, ranks[1:]):
        for u in a:
            targets = draw(st.lists(st.sampled_from(b), min_size=1,
                                    max_size=len(b), unique=True))
            for v in targets:
                g.edge(u, v)
        for v in b:  # every node needs a predecessor
            if not any((u, v) in g.edges for u in a):
                g.edge(draw(st.sampled_from(a)), v)
    # funnel all sinks into one terminal vertex (paper: single-sink DAG)
    g.add("sink")
    nxt = g.successors(False)
    for nname in list(g.nodes):
        if nname != "sink" and not nxt[nname]:
            g.edge(nname, "sink")
    return g


@settings(max_examples=40, deadline=None)
@given(random_dag())
def test_partition_invariants(g):
    groups = partition_sequential(g)
    names = [x for grp in groups for x in grp]
    # coverage + uniqueness over quantizable nodes
    assert sorted(names) == sorted(g.quantizable_nodes())
    # groups respect topological order: no edge from a later group back into
    # an earlier one
    order = {n: i for i, grp in enumerate(groups) for n in grp}
    for (a, b) in g.edges:
        if a in order and b in order:
            assert order[a] <= order[b]
