"""End-to-end AMP pipeline (Alg. 1) + baselines + serving consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import prefix_strategy, random_strategy
from repro.core.pipeline import AMPOptions, auto_mixed_precision, predicted_loss_mse
from repro.core.sensitivity import calibrate_sensitivity
from repro.models.registry import get_model
from repro.quant.qops import QuantContext


@pytest.fixture(scope="module")
def setup():
    m = get_model("llama3_1b", smoke=True)
    params = m.init(jax.random.key(0))
    batches = [{"tokens": jax.random.randint(jax.random.key(i), (2, 32), 0, 512),
                "labels": jax.random.randint(jax.random.key(i + 50), (2, 32), 0, 512)}
               for i in range(3)]
    sens = calibrate_sensitivity(lambda p, b, c: m.loss(p, b, c), params,
                                 batches)
    return m, params, batches, sens


@pytest.mark.parametrize("objective", ["ET", "TT", "M"])
def test_pipeline_objectives(setup, objective):
    m, params, batches, sens = setup
    opts = AMPOptions(tau=0.02, objective=objective)
    plan = auto_mixed_precision(m, params, batches, opts, sens=sens)
    assert plan.predicted_loss_mse <= plan.budget * (1 + 1e-9)
    assert plan.predicted_gain >= 0
    assert plan.n_quantized > 0
    if objective == "M":
        # memory objective quantizes linear layers only (Sec. 2.3.3)
        assert all(("matmul" not in n) for n in plan.assignment)
    # predicted mse from the assignment equals the solver's d_total
    assert np.isclose(predicted_loss_mse(sens, plan.assignment),
                      plan.predicted_loss_mse, rtol=1e-6, atol=1e-12)


def test_predicted_loss_mse_additive_over_disjoint_assignments(setup):
    """Eq. (6)/(23): the loss MSE of a union of disjoint assignments is the
    sum of the parts (the additivity the IP decomposition relies on)."""
    m, params, batches, sens = setup
    names = sorted(op.name for op in sens.ops)
    assert len(names) >= 9
    a1 = {n: "fp8_e4m3" for n in names[0:3]}
    a2 = {n: "fp8_e5m2" for n in names[3:6]}
    a3 = {n: "fp8_e4m3" for n in names[6:9]}
    parts = (predicted_loss_mse(sens, a1) + predicted_loss_mse(sens, a2)
             + predicted_loss_mse(sens, a3))
    merged = predicted_loss_mse(sens, {**a1, **a2, **a3})
    assert np.isclose(merged, parts, rtol=1e-12)
    assert predicted_loss_mse(sens, {}) == 0.0
    # reference-format entries contribute exactly zero
    assert predicted_loss_mse(sens, {names[0]: "bf16"}) == 0.0
    assert np.isclose(
        predicted_loss_mse(sens, {**a1, names[3]: "bf16"}),
        predicted_loss_mse(sens, a1), rtol=1e-12)
    # unknown op names fall back to zero sensitivity rather than crashing
    assert predicted_loss_mse(sens, {"ghost_op": "fp8_e4m3"}) == 0.0


def test_gain_monotone_in_tau(setup):
    m, params, batches, sens = setup
    gains = []
    for tau in (0.001, 0.01, 0.05):
        plan = auto_mixed_precision(
            m, params, batches, AMPOptions(tau=tau, objective="TT"), sens=sens)
        gains.append(plan.predicted_gain)
    assert gains[0] <= gains[1] <= gains[2]


def test_ip_beats_baselines(setup):
    """At equal budget, IP-TT gain >= Random/Prefix gain (optimality)."""
    from repro.core.timegain import TheoreticalGainModel
    from repro.hw.profiles import TPU_V5E
    m, params, batches, sens = setup
    opts = AMPOptions(tau=0.01, objective="TT")
    plan = auto_mixed_precision(m, params, batches, opts, sens=sens)
    budget = plan.budget
    names = [op.name for op in sens.ops]
    gm = TheoreticalGainModel(TPU_V5E)
    op_index = {op.name: op for op in sens.ops}

    def gain_of(assignment):
        return sum(gm.op_gain(op_index[n], f) for n, f in assignment.items())

    rnd = random_strategy(names, sens, budget, seed=3)
    pfx = prefix_strategy(names, sens, budget)
    assert plan.predicted_gain >= gain_of(rnd) - 1e-12
    assert plan.predicted_gain >= gain_of(pfx) - 1e-12
    # baselines respect the budget
    assert predicted_loss_mse(sens, rnd) <= budget * (1 + 1e-9)
    assert predicted_loss_mse(sens, pfx) <= budget * (1 + 1e-9)


def test_mp_serving_consistency(setup):
    """Prefill/decode under the MP plan stays close to bf16 serving."""
    m, params, batches, sens = setup
    plan = auto_mixed_precision(m, params, batches,
                                AMPOptions(tau=0.01, objective="TT"),
                                sens=sens)
    toks = batches[0]["tokens"][:, :16]
    ctx_mp = QuantContext(mode="mp", mp=plan.assignment)
    caches = m.init_cache(2, 20)
    lp_mp, caches = m.prefill(params, toks, caches, ctx_mp)
    caches2 = m.init_cache(2, 20)
    lp, caches2 = m.prefill(params, toks, caches2, QuantContext())
    a = np.asarray(lp_mp, np.float32)
    b = np.asarray(lp, np.float32)
    # logits deviate only mildly under the loss-MSE-constrained plan
    # (random-init logits are near zero, so the relative scale is generous)
    assert np.max(np.abs(a - b)) / (np.abs(b).max() + 1e-6) < 0.4


def test_wallclock_gain_model_additivity_interface(setup):
    """WallClockGainModel measures per-group deltas through the engine."""
    import time
    from repro.core.timegain import WallClockGainModel
    m, params, batches, sens = setup
    toks = batches[0]["tokens"][:, :16]

    def factory(assignment):
        ctx = QuantContext(mode="mp", mp=assignment) if assignment else QuantContext()
        fn = jax.jit(lambda p, t: m.apply(p, t, ctx))

        def run():
            jax.block_until_ready(fn(params, toks))
        return run

    gm = WallClockGainModel(run_factory=factory, n_iters=2, n_warmup=1)
    ops = sens.ops[:2]
    combos = [("bf16", "bf16"), ("fp8_e4m3", "fp8_e4m3")]
    gains = gm.gains(ops, combos)
    assert gains.shape == (2,)
    assert gains[0] == 0.0  # all-ref combo is zero by definition
