"""Quantization numerics: formats registry, casts, and the paper's noise
model (eq. 15-16): fake-quant error should match the alpha_f variance.

``hypothesis`` is optional: the qeinsum property test runs when it is
installed; a deterministic shape sweep covers the same check without it."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import FORMATS, QuantContext, alpha, fake_quant, get_format, quantize
from repro.quant.formats import BF16, FP8_E4M3, FP8_E5M2

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:          # pragma: no cover - exercised on minimal images
    HAS_HYPOTHESIS = False


def test_alpha_values():
    # alpha_f = 2^{-2 m_f} / 12
    assert np.isclose(alpha("fp8_e4m3"), 2.0 ** -6 / 12)
    assert np.isclose(alpha("fp8_e5m2"), 2.0 ** -4 / 12)
    assert np.isclose(alpha("bf16"), 2.0 ** -16 / 12)
    assert alpha("fp8_e5m2") > alpha("fp8_e4m3") > alpha("bf16")


def test_fake_quant_bf16_identity(rng):
    x = jax.random.normal(rng, (64, 64), jnp.bfloat16)
    y = fake_quant(x, "bf16")
    np.testing.assert_array_equal(np.asarray(x, np.float32),
                                  np.asarray(y, np.float32))


@pytest.mark.parametrize("fmt", ["fp8_e4m3", "fp8_e5m2"])
def test_quant_roundtrip_error_bounded(rng, fmt):
    f = get_format(fmt)
    x = jax.random.normal(rng, (256, 256), jnp.float32)
    y = fake_quant(x, fmt)
    rel = np.abs(np.asarray(y) - np.asarray(x)) / (np.abs(np.asarray(x)) + 1e-9)
    # relative error bounded by ~2^-(m+1) per element (up to scale clipping)
    bound = 2.0 ** (-f.mantissa_bits) * 1.5
    assert np.percentile(rel, 99) < bound, (fmt, np.percentile(rel, 99))


def test_fp4_roundtrip_snr(rng):
    """fp4 flushes tiny values to zero — per-element relative error is
    unbounded there; the energy-level SNR still matches the alpha model."""
    x = jax.random.normal(rng, (256, 256), jnp.float32)
    y = fake_quant(x, "fp4_e2m1")
    snr = float(np.mean((np.asarray(y) - np.asarray(x)) ** 2)
                / np.mean(np.asarray(x) ** 2))
    assert snr < 6 * alpha("fp4_e2m1"), snr


def test_noise_variance_matches_alpha_model(rng):
    """Empirical E[(x~-x)^2] ~= |x|^2 * alpha_f within a small factor.

    Validates the eq. (16) variance model our loss-MSE metric relies on.
    """
    x = jax.random.normal(rng, (2000, 128), jnp.float32)
    for fmt in ("fp8_e4m3", "fp8_e5m2"):
        y = fake_quant(x, fmt)
        err2 = np.mean((np.asarray(y) - np.asarray(x)) ** 2)
        pred = np.mean(np.asarray(x) ** 2) * alpha(fmt)
        ratio = err2 / pred
        # uniform-noise model is approximate (RTNE + per-tensor scaling):
        # accept a factor-of-3 window, centered near 1
        assert 0.3 < ratio < 3.0, (fmt, ratio)


def test_qtensor_real_cast(rng):
    x = jax.random.normal(rng, (64, 32), jnp.float32) * 5
    q = quantize(x, "fp8_e4m3")
    assert q.data.dtype == jnp.float8_e4m3fn
    back = q.dequantize(jnp.float32)
    rel = np.abs(np.asarray(back) - np.asarray(x)) / (np.abs(np.asarray(x)) + 1e-9)
    assert np.percentile(rel, 99) < 0.1


def _check_qeinsum_mp_vs_plain(m, k):
    from repro.quant import qops
    key = jax.random.key(m * 131 + k)
    x = jax.random.normal(key, (m, k), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (8, k), jnp.bfloat16)
    plain = qops.linear(QuantContext(), "op", x, w)
    mp = qops.linear(QuantContext(mode="mp", mp={"op": "fp8_e4m3"}), "op", x, w)
    # quantized result close but not identical
    diff = np.abs(np.asarray(mp, np.float32) - np.asarray(plain, np.float32))
    scale = np.abs(np.asarray(plain, np.float32)).max() + 1e-6
    assert diff.max() / scale < 0.2


@pytest.mark.parametrize("m,k", [(2, 2), (3, 17), (8, 64), (33, 5), (64, 64)])
def test_qeinsum_mp_vs_plain_cases(m, k):
    _check_qeinsum_mp_vs_plain(m, k)


if HAS_HYPOTHESIS:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 64), st.integers(2, 64))
    def test_qeinsum_mp_vs_plain(m, k):
        _check_qeinsum_mp_vs_plain(m, k)


def test_registry_collects_ops(rng):
    from repro.quant import qops
    reg = []
    ctx = QuantContext(registry=reg)
    x = jax.random.normal(rng, (4, 16), jnp.bfloat16)
    w = jax.random.normal(rng, (8, 16), jnp.bfloat16)
    qops.linear(ctx, "lin0", x, w)
    qops.bgemm(ctx, "bg0", "BC,KC->BK", x, w)
    assert [o.name for o in reg] == ["lin0", "bg0"]
    assert reg[0].kind == "linear" and reg[0].weight_elems == 8 * 16
    assert reg[1].kind == "bgemm" and reg[1].weight_elems == 0
    assert reg[0].macs == 4 * 16 * 8
