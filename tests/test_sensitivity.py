"""Sensitivity metric (Sec. 2.2): analytic checks + loss-MSE prediction."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import predicted_loss_mse
from repro.core.sensitivity import calibrate_sensitivity, collect_ops
from repro.quant import QuantContext, alpha, qops
from repro.models.registry import get_model


def _linear_loss(params, batch, ctx):
    """g = sum(x @ w^T): dg/dw = sum_n x_n; dg/dx = 1 @ w."""
    y = qops.linear(ctx, "lin", batch["x"], params["w"])
    return jnp.sum(y.astype(jnp.float32))


def test_sensitivity_analytic_linear(rng):
    """For g = sum(XW^T): s = ||X .* (1 W)||^2 + ||W .* (1^T X)||^2."""
    X = jax.random.normal(rng, (3, 5), jnp.float32)
    W = jax.random.normal(jax.random.fold_in(rng, 1), (4, 5), jnp.float32)
    params = {"w": W}
    sens = calibrate_sensitivity(_linear_loss, params, [{"x": X}])
    gx = jnp.ones((3, 4)) @ W            # dg/dX
    gw = jnp.ones((4, 3)) @ X            # dg/dW
    expected = float(jnp.sum((X * gx) ** 2) + jnp.sum((W * gw) ** 2))
    assert np.isclose(sens.sensitivity["lin"], expected, rtol=1e-5)


def test_collect_ops_matches_graph(rng):
    from repro.core.graphs import build_graph
    m = get_model("llama3_1b", smoke=True)
    params = m.init(rng)
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    ops = collect_ops(lambda p, b, c: m.loss(p, b, c), params, batch)
    got = {o.name for o in ops}
    want = set(build_graph(m).quantizable_nodes())
    assert want == got


def test_predicted_vs_measured_loss_mse(rng):
    """The centerpiece claim (paper Fig. 3a): sum_l s_l alpha_f predicts the
    measured quantized-loss MSE."""
    m = get_model("llama3_1b", smoke=True)
    params = m.init(rng)
    batches = [{"tokens": jax.random.randint(jax.random.fold_in(rng, i),
                                             (2, 32), 0, 512),
                "labels": jax.random.randint(jax.random.fold_in(rng, 77 + i),
                                             (2, 32), 0, 512)}
               for i in range(4)]
    loss_fn = lambda p, b, c: m.loss(p, b, c)
    sens = calibrate_sensitivity(loss_fn, params, batches)
    # quantize every op to fp8-e4m3
    assignment = {name: "fp8_e4m3" for name in sens.sensitivity}
    predicted = predicted_loss_mse(sens, assignment)
    ctx_mp = QuantContext(mode="mp", mp=assignment)
    ctx = QuantContext()
    errs = [(float(m.loss(params, b, ctx_mp)) - float(m.loss(params, b, ctx))) ** 2
            for b in batches]
    measured = float(np.mean(errs))
    # first-order model: right order of magnitude (paper shows ~tight match)
    assert predicted > 0 and measured > 0
    assert 0.2 < predicted / measured < 5.0, (predicted, measured)


def test_additivity_across_layers(rng):
    """d(assignment A u B) == d(A) + d(B) for disjoint op sets (eq. 23)."""
    m = get_model("llama3_1b", smoke=True)
    params = m.init(rng)
    batches = [{"tokens": jax.random.randint(rng, (2, 16), 0, 512),
                "labels": jax.random.randint(rng, (2, 16), 0, 512)}]
    sens = calibrate_sensitivity(lambda p, b, c: m.loss(p, b, c), params,
                                 batches)
    names = sorted(sens.sensitivity)
    A = {n: "fp8_e4m3" for n in names[:3]}
    B = {n: "fp8_e4m3" for n in names[3:6]}
    dA = predicted_loss_mse(sens, A)
    dB = predicted_loss_mse(sens, B)
    dAB = predicted_loss_mse(sens, {**A, **B})
    assert np.isclose(dAB, dA + dB, rtol=1e-9)


def test_loss_mse_reference_format_is_zero_noise(rng):
    """Eq. (23) measures noise *added* vs the reference run: ops assigned to
    (or left at) the reference format contribute d = 0, and the method is
    the same implementation as pipeline.predicted_loss_mse."""
    X = jax.random.normal(rng, (3, 5), jnp.float32)
    W = jax.random.normal(jax.random.fold_in(rng, 1), (4, 5), jnp.float32)
    sens = calibrate_sensitivity(_linear_loss, {"w": W}, [{"x": X}])
    assert sens.sensitivity["lin"] > 0
    # empty assignment (everything at the reference) predicts zero MSE
    assert sens.loss_mse({}) == 0.0
    # explicitly assigning the reference format is also zero, not s*alpha_bf16
    assert sens.loss_mse({"lin": "bf16"}) == 0.0
    # and both public entry points agree on a quantized assignment
    asg = {"lin": "fp8_e4m3"}
    assert sens.loss_mse(asg) == predicted_loss_mse(sens, asg)
    assert sens.loss_mse(asg) == sens.d_layer("lin", "fp8_e4m3")


def test_calibration_traces_once_per_batch_signature(rng, monkeypatch):
    """Probe shapes are cached on the batch-shape signature: steady-state
    calibration does ONE abstract trace total, even with op chunking over
    many batches; a new batch shape costs exactly one more."""
    X = jax.random.normal(rng, (3, 5), jnp.float32)
    W = jax.random.normal(jax.random.fold_in(rng, 1), (4, 5), jnp.float32)
    calls = {"n": 0}
    orig = jax.eval_shape

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(jax, "eval_shape", counting)
    same_shape = [{"x": X}, {"x": X + 1.0}, {"x": X * 2.0}]
    sens = calibrate_sensitivity(_linear_loss, {"w": W}, same_shape,
                                 op_chunk=1)
    assert calls["n"] == 1, calls
    assert sens.n_batches == 3

    calls["n"] = 0
    mixed = same_shape + [{"x": jnp.concatenate([X, X], axis=0)}]
    sens = calibrate_sensitivity(_linear_loss, {"w": W}, mixed, op_chunk=1)
    assert calls["n"] == 2, calls
    assert sens.n_batches == 4


def test_format_scaling(rng):
    """d_{l,f} scales exactly with alpha_f (eq. 22)."""
    m = get_model("llama3_1b", smoke=True)
    params = m.init(rng)
    batches = [{"tokens": jax.random.randint(rng, (2, 16), 0, 512),
                "labels": jax.random.randint(rng, (2, 16), 0, 512)}]
    sens = calibrate_sensitivity(lambda p, b, c: m.loss(p, b, c), params,
                                 batches)
    name = sorted(sens.sensitivity)[0]
    d3 = predicted_loss_mse(sens, {name: "fp8_e4m3"})
    d2 = predicted_loss_mse(sens, {name: "fp8_e5m2"})
    assert np.isclose(d2 / d3, alpha("fp8_e5m2") / alpha("fp8_e4m3"))
