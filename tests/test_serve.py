"""Serving subsystem: continuous batching vs one-shot token parity, mid-decode
admission, slot pool invariants, scheduler policy, and the MPPlan handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mpconfig import MPPlan, as_assignment
from repro.models.registry import get_model
from repro.quant.qops import QuantContext
from repro.serve import (CachePool, ContinuousBatchingEngine, Request,
                         Scheduler, ServeEngine)

MP_ASSIGNMENT = {
    "layers/0/attn/q_proj": "fp8_e4m3",
    "layers/1/mlp/down_proj": "fp8_e4m3",
    "lm_head": "fp8_e4m3",
}


@pytest.fixture(scope="module")
def model():
    return get_model("llama3_1b", smoke=True)


@pytest.fixture(scope="module")
def params(model):
    return model.init(jax.random.key(0))


@pytest.fixture(scope="module")
def prompts():
    rng = np.random.default_rng(42)
    return [rng.integers(0, 500, size=12).astype(np.int32) for _ in range(4)]


def _oneshot_reference(model, params, prompts, max_new, mp=None):
    eng = ServeEngine(model, mp=mp, donate=False)
    out = {}
    for i, p in enumerate(prompts):
        r = eng.generate(params, {"tokens": jnp.asarray(p)[None]},
                         max_new_tokens=max_new)
        out[i] = np.asarray(r.tokens)[0]
    return out


# ---------------------------------------------------------------------------
# token parity: continuous batching == one-shot greedy decode
# ---------------------------------------------------------------------------


def test_continuous_matches_oneshot_tokens(model, params, prompts):
    ref = _oneshot_reference(model, params, prompts, max_new=6)
    eng = ContinuousBatchingEngine(model, n_slots=4, max_len=32)
    reqs = [Request(rid=i, tokens=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    summ = eng.serve(params, reqs)
    assert set(summ.results) == set(range(len(prompts)))
    for i in range(len(prompts)):
        np.testing.assert_array_equal(summ.results[i].tokens, ref[i])
    assert summ.tokens_per_s > 0
    assert all(r.ttft_s > 0 for r in summ.results.values())


def test_continuous_matches_batched_oneshot(model, params, prompts):
    """Lock-step batched generate() and continuous serving agree exactly."""
    eng1 = ServeEngine(model, donate=False)
    batch = {"tokens": jnp.asarray(np.stack(prompts))}
    ref = np.asarray(eng1.generate(params, batch, max_new_tokens=5).tokens)
    eng2 = ContinuousBatchingEngine(model, n_slots=len(prompts), max_len=32)
    reqs = [Request(rid=i, tokens=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    summ = eng2.serve(params, reqs)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(summ.results[i].tokens, ref[i])


def test_continuous_matches_oneshot_with_mp_plan(model, params, prompts):
    """Parity holds under an MP assignment, handed over as an MPPlan."""
    ref = _oneshot_reference(model, params, prompts[:2], max_new=5,
                             mp=MP_ASSIGNMENT)
    plan = MPPlan(assignment=dict(MP_ASSIGNMENT), groups=[], objective="ET",
                  tau=0.01, budget=0.0, predicted_loss_mse=0.0,
                  predicted_gain=0.0)
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32, mp=plan)
    assert eng.mp == MP_ASSIGNMENT
    reqs = [Request(rid=i, tokens=p, max_new_tokens=5)
            for i, p in enumerate(prompts[:2])]
    summ = eng.serve(params, reqs)
    for i in range(2):
        np.testing.assert_array_equal(summ.results[i].tokens, ref[i])


def test_late_admission_no_cache_corruption(model, params, prompts):
    """More requests than slots, staggered arrivals: a request admitted
    mid-decode reuses a slot without disturbing in-flight sequences."""
    ref = _oneshot_reference(model, params, prompts, max_new=6)
    eng = ContinuousBatchingEngine(model, n_slots=2, max_len=32)
    # rid 0/1 fill both slots; rid 2 queues until a slot frees; rid 3
    # arrives while rid 2 is mid-decode and joins its batch
    arrivals = [0, 0, 1, 8]
    reqs = [Request(rid=i, tokens=p, max_new_tokens=6, arrival=arrivals[i])
            for i, p in enumerate(prompts)]
    summ = eng.serve(params, reqs)
    for i in range(len(prompts)):
        np.testing.assert_array_equal(summ.results[i].tokens, ref[i])
    # the late requests really were admitted after decode began, rid 3
    # strictly later than rid 2 (i.e. it joined an in-flight batch)
    assert summ.results[3].admitted_step > summ.results[2].admitted_step >= 1
    assert summ.results[3].admitted_step < summ.results[2].finished_step
    # 4 requests through 2 slots: at least two slot reuses happened
    assert summ.n_steps >= 10


def test_single_token_requests(model, params, prompts):
    """max_new_tokens=1 finishes at prefill and frees its slot immediately."""
    eng = ContinuousBatchingEngine(model, n_slots=1, max_len=32)
    reqs = [Request(rid=i, tokens=p, max_new_tokens=1)
            for i, p in enumerate(prompts[:3])]
    summ = eng.serve(params, reqs)
    ref = _oneshot_reference(model, params, prompts[:3], max_new=1)
    for i in range(3):
        np.testing.assert_array_equal(summ.results[i].tokens, ref[i])
    assert summ.n_steps == 0


# ---------------------------------------------------------------------------
# per-slot position vectors (the decode-path change under the engine)
# ---------------------------------------------------------------------------


def test_vector_pos_decode_matches_scalar(model, params):
    toks = jnp.asarray(np.random.default_rng(3).integers(0, 500, (2, 8)),
                       jnp.int32)
    ctx = QuantContext()

    def run(pos):
        caches = model.init_cache(2, 16)
        _, caches = model.prefill(params, toks, caches, ctx)
        tok = jnp.array([[5], [9]], jnp.int32)
        return model.decode_step(params, tok, pos, caches, ctx)

    logits_s, caches_s = run(jnp.array(8, jnp.int32))
    logits_v, caches_v = run(jnp.array([8, 8], jnp.int32))
    np.testing.assert_array_equal(np.asarray(logits_s, np.float32),
                                  np.asarray(logits_v, np.float32))
    for (ps, ls), (pv, lv) in zip(
            jax.tree_util.tree_leaves_with_path(caches_s),
            jax.tree_util.tree_leaves_with_path(caches_v)):
        np.testing.assert_array_equal(np.asarray(ls, np.float32),
                                      np.asarray(lv, np.float32), err_msg=str(ps))


# ---------------------------------------------------------------------------
# ttft regression (satellite: it used to read self.model_params)
# ---------------------------------------------------------------------------


def test_ttft_without_prior_generate(model, params, prompts):
    eng = ServeEngine(model, donate=False)
    t = eng.ttft(params, {"tokens": jnp.asarray(prompts[0])[None]},
                 max_len=16, n_iters=1, n_warmup=0)
    assert t > 0


# ---------------------------------------------------------------------------
# cache pool
# ---------------------------------------------------------------------------


def test_cache_pool_alloc_free(model):
    pool = CachePool(model, n_slots=2, max_len=8)
    a, b = pool.alloc(), pool.alloc()
    assert {a, b} == {0, 1} and pool.n_free == 0
    with pytest.raises(RuntimeError):
        pool.alloc()
    pool.free(a)
    assert pool.n_free == 1 and pool.alloc() == a


def test_cache_pool_insert_overwrites_only_its_slot(model):
    pool = CachePool(model, n_slots=3, max_len=8)
    ones = jax.tree.map(lambda x: jnp.ones((1,) + x.shape[1:], x.dtype),
                        model.init_cache(1, 8))
    pool.insert(1, ones)
    for path, leaf in jax.tree_util.tree_leaves_with_path(pool.caches):
        arr = np.asarray(leaf, np.float32)
        assert np.all(arr[1] == 1), path
        assert np.all(arr[0] != 1) or arr[0].size == 0, path
        assert np.all(arr[2] != 1) or arr[2].size == 0, path


# ---------------------------------------------------------------------------
# scheduler policy
# ---------------------------------------------------------------------------


def _req(rid, arrival=0, max_new=4):
    return Request(rid=rid, tokens=np.arange(4, dtype=np.int32),
                   max_new_tokens=max_new, arrival=arrival)


def test_scheduler_fcfs_and_arrival_gating():
    s = Scheduler()
    s.submit(_req(0, arrival=0))
    s.submit(_req(1, arrival=2))
    st0 = s.pop_admissible(0)
    assert st0.request.rid == 0
    assert s.pop_admissible(0) is None          # rid 1 hasn't arrived
    assert s.next_arrival() == 2
    assert s.pop_admissible(2).request.rid == 1
    assert s.pop_admissible(2) is None          # queue drained


def test_scheduler_lifecycle_bookkeeping():
    s = Scheduler()
    st = s.submit(_req(7, max_new=3))
    st = s.pop_admissible(0)
    s.start(st, slot=0, first_token=11, ttft_s=0.5, now=0)
    assert s.running[0] is st and st.out_tokens == [11]
    assert st.next_pos == 4                      # == prompt_len
    s.record_token(0, 12)
    s.record_token(0, 13)
    assert st.done
    res = s.finish(st, now=2)
    assert not s.running and not s.has_work()
    np.testing.assert_array_equal(res.tokens, [11, 12, 13])
    assert res.finished_step == 2 and res.ttft_s == 0.5


def test_scheduler_rejects_duplicate_rid():
    s = Scheduler()
    s.submit(_req(1))
    with pytest.raises(AssertionError):
        s.submit(_req(1))


# ---------------------------------------------------------------------------
# MPPlan -> engine handoff
# ---------------------------------------------------------------------------


def test_as_assignment_normalizes():
    assert as_assignment(None) is None
    assert as_assignment({}) is None
    assert as_assignment({"a": "bf16"}) is None      # ref format drops out
    assert as_assignment({"a": "fp8_e4m3", "b": "bf16"}) == {"a": "fp8_e4m3"}
    plan = MPPlan(assignment={"x": "fp8_e5m2"}, groups=[["x"]], objective="M",
                  tau=0.1, budget=1.0, predicted_loss_mse=0.0,
                  predicted_gain=1.0)
    assert as_assignment(plan) == {"x": "fp8_e5m2"}
    with pytest.raises(TypeError):
        as_assignment(["not", "a", "plan"])


def test_mpplan_unknown_ops():
    plan = MPPlan(assignment={"a": "fp8_e4m3", "ghost": "fp8_e4m3"},
                  groups=[], objective="ET", tau=0.1, budget=1.0,
                  predicted_loss_mse=0.0, predicted_gain=1.0)
    assert plan.unknown_ops({"a", "b"}) == {"ghost"}
    assert plan.unknown_ops({"a", "ghost"}) == set()
